"""WAL / snapshot / recovery unit contracts, plus the checked-envelope
hardening of the derived-state caches (``columnar.persist``).

The chaos harness (``test_chaos.py``) proves the end-to-end crash story;
this file pins the unit-level invariants it rests on: record framing and
group-commit accounting, truncate-at-first-torn-record, sequence-floor
preservation across rotation, snapshot atomicity + corruption fallback,
replay fidelity for every mutation kind, and the data-epoch token that
keeps persisted plan/feedback caches honest across lineages.
"""
import os
import pickle
import zlib

import numpy as np
import pytest

from repro.columnar import (Durability, DurabilityError, ExecConfig,
                            StreamSession, Table, WriteAheadLog, run_query)
from repro.columnar.queries import random_tree

CFG = ExecConfig(planner="deepfish", engine="numpy")


def _table(n=200, seed=0):
    rng = np.random.default_rng(seed)
    return Table({"a": rng.normal(size=n),
                  "b": rng.integers(0, 50, size=n).astype(np.int64),
                  "s": rng.choice(np.array(["ash", "oak", "pine"]), size=n)})


def _assert_same_table(got: Table, want: Table):
    assert set(got.columns) == set(want.columns)
    assert got.n_records == want.n_records
    assert got.version == want.version
    for name, col in want.columns.items():
        assert got.columns[name].dtype == col.dtype
        np.testing.assert_array_equal(got.columns[name], col)
    gt = np.zeros(got.n_records, bool)
    wt = np.zeros(want.n_records, bool)
    if got._tombstones is not None:
        gt[: len(got._tombstones)] = got._tombstones
    if want._tombstones is not None:
        wt[: len(want._tombstones)] = want._tombstones
    np.testing.assert_array_equal(gt, wt)


# -- the log ------------------------------------------------------------------

def test_wal_log_commit_replay_roundtrip(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    recs = [("append", {"rows": {"a": np.arange(3)}}),
            ("delete", {"rows": np.array([1])}),
            ("compact", {})]
    for kind, payload in recs:
        wal.log(kind, payload)
    assert wal.uncommitted == 3 and wal.committed_seq == 0
    assert wal.commit() is not None
    assert wal.uncommitted == 0 and wal.committed_seq == 3
    assert wal.commit() is None                 # idle commit is free
    wal.close()

    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.last_seq == 3 == wal2.committed_seq
    replayed = list(wal2.replay())
    assert [(s, k) for s, k, _ in replayed] == \
        [(1, "append"), (2, "delete"), (3, "compact")]
    np.testing.assert_array_equal(replayed[0][2]["rows"]["a"], np.arange(3))
    assert list(wal2.replay(after_seq=2)) == replayed[2:]
    wal2.close()


def test_wal_sync_policies(tmp_path):
    always = WriteAheadLog(str(tmp_path / "a"), sync="always")
    always.log("compact", {})
    assert always.uncommitted == 0 and always.commits == 1
    always.close()
    grouped = WriteAheadLog(str(tmp_path / "g"), sync="group",
                            group_max_records=4)
    for _ in range(3):
        grouped.log("compact", {})
    assert grouped.uncommitted == 3             # below the cap: buffered
    grouped.log("compact", {})                  # cap reached: auto-commit
    assert grouped.uncommitted == 0
    grouped.close()
    with pytest.raises(ValueError):
        WriteAheadLog(str(tmp_path / "x"), sync="fsync-sometimes")


def test_wal_truncates_torn_tail(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    for i in range(4):
        wal.log("append", {"i": i})
    wal.commit()
    path = wal._tail_path
    wal.close()
    with open(path, "ab") as f:                 # torn final frame
        f.write(b"\x01\x02\x03garbage")
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.last_seq == 4
    assert wal2.truncated_records == 1 and wal2.truncated_bytes > 0
    assert [s for s, _, _ in wal2.replay()] == [1, 2, 3, 4]
    # the torn tail was physically removed: reopening is clean
    wal2.log("append", {"i": 4})
    wal2.commit()
    wal2.close()
    wal3 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal3.last_seq == 5 and wal3.truncated_records == 0
    wal3.close()


def test_wal_bitflip_drops_suffix(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    for i in range(6):
        wal.log("append", {"i": i})
    wal.commit()
    path = wal._tail_path
    wal.close()
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF                # flip a bit mid-log
    open(path, "wb").write(bytes(data))
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    seqs = [s for s, _, _ in wal2.replay()]
    assert wal2.truncated_records == 1
    assert seqs == list(range(1, len(seqs) + 1))    # a clean prefix
    assert wal2.last_seq == (seqs[-1] if seqs else 0) < 6
    wal2.close()


def test_wal_rotation_pins_sequence_floor(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    for i in range(5):
        wal.log("append", {"i": i})
    wal.rotate(covered_seq=5)                   # old segment GC'd
    assert wal.segments_gced == 1
    wal.close()
    # the surviving segment is empty, but its NAME pins the floor
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.last_seq == 5
    assert wal2.log("append", {"i": 5}) == 6
    wal2.close()


# -- snapshots + recovery -----------------------------------------------------

def test_recover_snapshot_plus_tail(tmp_path):
    t = _table()
    dur = Durability(str(tmp_path / "d"), snapshot_every=None)
    dur.attach(t)
    t.append({"a": np.ones(8), "b": np.arange(8),
              "s": np.array(["oak"] * 8)})
    dur.snapshot()
    t.delete(np.arange(4))
    t.compact()
    dur.commit()
    dur.close()
    dur2, t2, info = Durability.recover(str(tmp_path / "d"))
    assert info["snapshot_seq"] == 2            # create + append covered
    assert info["replayed_records"] == 2        # delete + compact tail
    assert info["epoch"] == dur.epoch
    _assert_same_table(t2, t)
    dur2.close()


def test_recover_skips_corrupt_snapshot(tmp_path):
    t = _table()
    dur = Durability(str(tmp_path / "d"), snapshot_every=None,
                     keep_snapshots=2)
    dur.attach(t)
    t.append({"a": np.ones(4), "b": np.arange(4),
              "s": np.array(["ash"] * 4)})
    dur.snapshot()
    t.delete([0, 1])
    newest = dur.snapshot()
    dur.close()
    blob = bytearray(open(os.path.join(newest, "state.pkl"), "rb").read())
    blob[10] ^= 0x40                            # bit flip: CRC mismatch
    open(os.path.join(newest, "state.pkl"), "wb").write(bytes(blob))
    dur2, t2, info = Durability.recover(str(tmp_path / "d"))
    assert info["snapshots_skipped"] == 1       # fell back one snapshot
    assert info["replayed_records"] == 1        # ... and replayed further
    _assert_same_table(t2, t)
    dur2.close()
    # manifest format drift is refused the same way
    with open(os.path.join(newest, "manifest.json"), "w") as f:
        f.write('{"format": 999}')
    dur3, t3, info3 = Durability.recover(str(tmp_path / "d"))
    assert info3["snapshots_skipped"] == 1
    _assert_same_table(t3, t)
    dur3.close()


def test_recover_rebuilds_dicts_and_set_column(tmp_path):
    t = _table(seed=3)
    assert t.dict_column("s") is not None       # built dictionary state
    dur = Durability(str(tmp_path / "d"), snapshot_every=None)
    dur.attach(t)
    t.append({"a": np.zeros(6), "b": np.arange(6),
              "s": np.array(["elm", "oak", "elm", "ash", "elm", "fir"])})
    t.set_column("b", np.arange(t.n_records).astype(np.int64))
    dur.snapshot()
    dur.close()
    dur2, t2, _ = Durability.recover(str(tmp_path / "d"))
    _assert_same_table(t2, t)
    d1, d2 = t.dict_column("s"), t2.dict_column("s")
    np.testing.assert_array_equal(d1.codes, d2.codes)
    np.testing.assert_array_equal(d1.values, d2.values)
    assert d1.sorted_n == d2.sorted_n           # same merge state
    # and queries agree bit-for-bit on the recovered table
    rng = np.random.default_rng(0)
    tree = random_tree(t, 4, 2, rng)
    np.testing.assert_array_equal(run_query(tree, t, config=CFG)[0],
                                  run_query(tree, t2, config=CFG)[0])
    dur2.close()


def test_delete_is_wal_logged_but_not_mutlogged(tmp_path):
    t = _table()
    v0 = t.version
    dur = Durability(str(tmp_path / "d"), snapshot_every=None)
    dur.attach(t)
    t.delete([1, 2, 3])
    # tombstones never invalidate prefix caches: delta_since still
    # answers for the pre-delete version (version bump, rows untouched)
    assert t.delta_since(v0) is not None
    assert all(kind != "delete" for _, kind, _ in t._mutlog)
    dur.commit()
    dur.close()
    _, t2, info = Durability.recover(str(tmp_path / "d"))
    assert info["replayed_records"] == 2        # create + delete
    _assert_same_table(t2, t)


def test_lifecycle_misuse_raises(tmp_path):
    d = str(tmp_path / "d")
    dur = Durability(d, snapshot_every=None)
    dur.attach(_table())
    dur.close()
    with pytest.raises(DurabilityError):        # split-brain guard
        Durability(d).attach(_table())
    with pytest.raises(DurabilityError):        # nothing recoverable
        Durability.recover(str(tmp_path / "empty"))
    with pytest.raises(ValueError):             # recover needs durable=
        StreamSession(None, config=CFG)


# -- the stream acknowledgement boundary --------------------------------------

def test_stream_group_commit_per_drain(tmp_path):
    t = _table(1000, seed=1)
    s = StreamSession(t, config=CFG, durable=str(tmp_path / "d"))
    wal = s.durability.wal
    commits0 = wal.commits
    for i in range(5):
        s.append({"a": np.ones(4) * i, "b": np.arange(4),
                  "s": np.array(["oak"] * 4)})
    assert wal.uncommitted == 5                 # buffered, no fsync yet
    assert wal.commits == commits0
    fut = s.submit(random_tree(t, 4, 2, np.random.default_rng(2)))
    s.drain()
    fut.result(timeout=30)
    # ONE fsync covered all five appends, before the future resolved
    assert wal.uncommitted == 0
    assert wal.commits == commits0 + 1
    s.append({"a": np.zeros(2), "b": np.arange(2),
              "s": np.array(["ash", "elm"])})
    assert s.sync() == wal.last_seq             # explicit boundary
    assert wal.uncommitted == 0
    h = s.health()
    assert h["durable"] and h["wal"]["uncommitted"] == 0
    assert h["recovery"] == {"recovered": False}
    s.close()
    # close() snapshots: restart replays nothing
    s2 = StreamSession(None, config=CFG, durable=str(tmp_path / "d"))
    assert s2.recovery_info["replayed_records"] == 0
    _assert_same_table(s2.table, t)
    s2.close()


def test_stream_wal_sync_always_commits_each_mutation(tmp_path):
    t = _table(300, seed=2)
    s = StreamSession(t, config=CFG, durable=str(tmp_path / "d"),
                      wal_sync="always")
    wal = s.durability.wal
    for i in range(3):
        s.append({"a": np.ones(2) * i, "b": np.arange(2),
                  "s": np.array(["oak", "ash"])})
        assert wal.uncommitted == 0             # fsync per mutation
    s.close()


# -- persist hardening (checked envelope + data epoch) ------------------------

def _warm_session(tmp_path, cache_dir):
    t = _table(2000, seed=5)
    s = StreamSession(t, config=CFG, cache_dir=cache_dir)
    futs = [s.submit(random_tree(t, 4, 2, np.random.default_rng(i)))
            for i in range(3)]
    s.drain()
    for f in futs:
        f.result(timeout=30)
    s.close()                                   # flushes checked caches


@pytest.mark.parametrize("damage", ["truncate", "bitflip", "empty"])
def test_persist_corrupt_cache_cold_starts(tmp_path, damage):
    from repro.columnar import persist
    cache_dir = str(tmp_path / "warm")
    _warm_session(tmp_path, cache_dir)
    for name in (persist.PLAN_CACHE_FILE, persist.FEEDBACK_FILE):
        path = os.path.join(cache_dir, name)
        data = open(path, "rb").read()
        assert len(data) > 64
        if damage == "truncate":
            open(path, "wb").write(data[: len(data) // 2])
        elif damage == "bitflip":
            flipped = bytearray(data)
            flipped[len(flipped) // 2] ^= 0x10
            open(path, "wb").write(bytes(flipped))
        else:
            open(path, "wb").write(b"")
    s = StreamSession(_table(2000, seed=5), config=CFG,
                      cache_dir=cache_dir)
    assert s.restore_info["plans"] == 0         # clean cold start
    assert s.restore_info.get("feedback_keys", 0) == 0
    fut = s.submit(random_tree(s.table, 4, 2, np.random.default_rng(0)))
    s.drain()
    assert fut.result(timeout=30) is not None   # ... and still serves
    s.close()


def test_persist_epoch_token(tmp_path):
    from repro.columnar.persist import _dump_checked, _load_checked
    path = str(tmp_path / "cache.pkl")
    _dump_checked({"x": 1}, path, epoch="lineage-A")
    assert _load_checked(path, epoch="lineage-A") == {"x": 1}
    assert _load_checked(path, epoch="lineage-B") is None   # foreign data
    # one-sided epochs stay compatible (legacy files / non-durable runs)
    assert _load_checked(path, epoch=None) == {"x": 1}
    _dump_checked({"x": 2}, path, epoch=None)
    assert _load_checked(path, epoch="lineage-A") == {"x": 2}


def test_persist_format_drift_refused(tmp_path):
    from repro.columnar.persist import FORMAT, _load_checked
    path = str(tmp_path / "cache.pkl")
    blob = pickle.dumps({"x": 1})
    with open(path, "wb") as f:
        pickle.dump({"format": FORMAT - 1, "crc": zlib.crc32(blob),
                     "epoch": None, "blob": blob}, f)
    assert _load_checked(path) is None


def test_durable_stream_caches_survive_recovery_same_epoch(tmp_path):
    """Caches persisted by a durable session warm the RECOVERED session
    (same lineage) — and are refused by a session over different data."""
    cache_dir = str(tmp_path / "warm")
    data_dir = str(tmp_path / "data")
    t = _table(2000, seed=6)
    s = StreamSession(t, config=CFG, durable=data_dir,
                      cache_dir=cache_dir)
    futs = [s.submit(random_tree(t, 4, 2, np.random.default_rng(i)))
            for i in range(3)]
    s.drain()
    for f in futs:
        f.result(timeout=30)
    s.close()

    s2 = StreamSession(None, config=CFG, durable=data_dir,
                       cache_dir=cache_dir)
    assert s2.recovery_info is not None
    assert s2.restore_info["plans"] >= 3        # same epoch: warm start
    s2.close()

    other = StreamSession(_table(2000, seed=6), config=CFG,
                          durable=str(tmp_path / "other"),
                          cache_dir=cache_dir)
    assert other.restore_info["plans"] == 0     # different lineage: cold
    other.close()
