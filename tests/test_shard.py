"""Block-sharded tape execution in a SUBPROCESS (host-device count is
locked at first jax init, so multi-device runs cannot share the main
pytest process — same pattern as test_dryrun_small.py):

* differential sweep: planners x shard counts {1, 2, 8} x append/delete
  sequences, bit-identical to the single-device numpy oracle,
* one (collective) host sync per query, one bundled sync per lockstep
  batch, under every shard count,
* ``programs_compiled_on_append == 0`` preserved under sharding (zone
  masks stay runtime inputs),
* shard-local delta re-upload: a small append lands on one shard.

An in-process smoke (1 device, shards=1) covers the shard_map wrapper
without the subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.columnar import (ExecConfig, QuerySession, ShardedTapeBackend,
                                make_forest_table, random_tree, run_query)
    from repro.columnar.device import _TAPE_PROGRAMS

    def traces():
        return (len(_TAPE_PROGRAMS),
                sum(p._cache_size() for p in _TAPE_PROGRAMS.values()))

    BLOCK = 4096
    t = make_forest_table(50_000, n_dup=2, seed=7)
    trees = [random_tree(t, 6, 3, np.random.default_rng(s))
             for s in (1, 2, 4)]
    planners = ("shallowfish", "deepfish")

    def oracle(tree, planner="deepfish"):
        return run_query(tree, t, config=ExecConfig(planner=planner))[0]

    out = {"identical": True, "one_sync": True}

    # -- differential sweep: planners x shard counts ----------------------
    for S in (1, 2, 8):
        be = ShardedTapeBackend(t, block=BLOCK, shards=S)
        for pl in planners:
            cfg = ExecConfig(planner=pl, engine="tape", block=BLOCK,
                             shards=S)
            for tree in trees:
                s0 = be.host_syncs
                got, _, _ = run_query(tree, t, config=cfg, backend=be)
                out["identical"] &= bool(
                    np.array_equal(got, oracle(tree, pl)))
                out["one_sync"] &= (be.host_syncs - s0 == 1)
        out[f"mesh_{S}"] = be.shards

    # -- append / delete sequence under 8 shards --------------------------
    be = ShardedTapeBackend(t, block=BLOCK, shards=8)
    cfg = ExecConfig(planner="deepfish", engine="tape", block=BLOCK,
                     shards=8)
    for tree in trees:
        run_query(tree, t, config=cfg, backend=be)
    p0, c0 = traces()
    t.append({k: np.asarray(v)[:900] for k, v in t.columns.items()})
    be.refresh()
    out["delta_upload_shards"] = be.delta_upload_shards
    ok = True
    for tree in trees:
        got, _, _ = run_query(tree, t, config=cfg, backend=be)
        ok &= bool(np.array_equal(got, oracle(tree)))
    t.delete(np.arange(0, 5000, 7))
    for tree in trees:
        got, _, _ = run_query(tree, t, config=cfg, backend=be)
        ok &= bool(np.array_equal(got, oracle(tree)))
    p1, c1 = traces()
    out["post_mutation_identical"] = ok
    out["programs_compiled_on_append"] = (p1 - p0) + (c1 - c0)

    # -- config routing + lockstep batch: one bundled collective sync -----
    got, _, be2 = run_query(trees[0], t, config=cfg)
    out["config_builds_sharded"] = type(be2).__name__ == "ShardedTapeBackend"
    sess = QuerySession(t, config=cfg.replace(batched=True))
    res = sess.execute(trees)
    out["lockstep_identical"] = all(
        np.array_equal(b, oracle(tr)) for b, tr in zip(res.bitmaps, trees))
    out["lockstep_syncs"] = res.backend.host_syncs
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def shard_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


def test_sharded_bit_identical_to_oracle(shard_results):
    assert shard_results["identical"] is True
    for s in (1, 2, 8):
        assert shard_results[f"mesh_{s}"] == s


def test_one_collective_sync_per_query(shard_results):
    assert shard_results["one_sync"] is True


def test_append_delete_stay_identical(shard_results):
    assert shard_results["post_mutation_identical"] is True


def test_appends_never_retrace_under_sharding(shard_results):
    assert shard_results["programs_compiled_on_append"] == 0


def test_small_append_lands_on_one_shard(shard_results):
    assert shard_results["delta_upload_shards"] == 1


def test_config_routes_to_sharded_backend(shard_results):
    assert shard_results["config_builds_sharded"] is True


def test_lockstep_batch_one_bundled_sync(shard_results):
    assert shard_results["lockstep_identical"] is True
    assert shard_results["lockstep_syncs"] == 1


# ---------------------------------------------------------------------------
# in-process smoke: the shard_map wrapper on a 1-device mesh
# ---------------------------------------------------------------------------

def test_shard_map_wrapper_single_device(forest):
    from repro.columnar import (ExecConfig, ShardedTapeBackend, random_tree,
                                run_query)
    tree = random_tree(forest, 6, 3, np.random.default_rng(3))
    want, _, _ = run_query(tree, forest, config=ExecConfig(
        planner="deepfish"))
    be = ShardedTapeBackend(forest, shards=1)
    got, _, _ = run_query(tree, forest, config=ExecConfig(
        planner="deepfish", engine="tape"), backend=be)
    assert np.array_equal(got, want)
    assert be.host_syncs == 1


def test_sharded_rejects_pallas_kernels(forest):
    from repro.columnar import ConfigError, ShardedTapeBackend
    with pytest.raises(ConfigError):
        ShardedTapeBackend(forest, kernels="pallas", shards=1)


def test_too_many_shards_rejected(forest):
    # the main process sees ONE device (conftest contract)
    from repro.columnar import ConfigError, ShardedTapeBackend
    with pytest.raises(ConfigError):
        ShardedTapeBackend(forest, shards=4)
