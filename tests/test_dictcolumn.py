"""Dictionary-encoded string columns + the code-space rewrite.

Covers: DictColumn round-trip invariants, Table dictionary caching and
invalidation (set_column / rebinding), code-column resolution, the
``codes_expression`` rewrite (exact masks, run fragmentation, degenerate
always-true/false atoms) and — when hypothesis is installed — property
tests asserting rewritten masks equal the oracle on random vocabularies.
"""
import numpy as np
import pytest

from repro.columnar import Table, rewrite_string_atoms
from repro.columnar.table import _apply_op, build_dict_column
from repro.core.predicate import (And, Atom, Node, code_column,
                                  codes_expression, decode_column, normalize)

VOCAB = np.array(["bergen", "oslo", "stavanger", "tromso", "trondheim"])


@pytest.fixture()
def city_table():
    rng = np.random.default_rng(3)
    n = 2000
    return Table({
        "x": rng.normal(size=n).astype(np.float32),
        "city": rng.choice(VOCAB, n),
    })


def eval_code_expr(node: Node, codes: np.ndarray) -> np.ndarray:
    """Evaluate a code-space expression directly on a codes vector."""
    if isinstance(node, Atom):
        return _apply_op(node, codes)
    combine = np.logical_and if isinstance(node, And) else np.logical_or
    out = None
    for c in node.children:
        m = eval_code_expr(c, codes)
        out = m if out is None else combine(out, m)
    return out


# -- DictColumn --------------------------------------------------------------

def test_dict_column_round_trip(city_table):
    dc = city_table.dict_column("city")
    assert dc is not None
    # sorted unique dictionary, int32 codes, exact decode
    assert np.array_equal(dc.values, np.sort(np.unique(city_table["city"])))
    assert dc.codes.dtype == np.int32
    np.testing.assert_array_equal(dc.decode(), city_table["city"])
    assert abs(dc.freqs.sum() - 1.0) < 1e-9
    for i, v in enumerate(dc.values):
        assert dc.encode(v) == i
    assert dc.encode("nowhere") is None


def test_numeric_columns_have_no_dictionary(city_table):
    assert city_table.dict_column("x") is None


def test_dict_cache_and_invalidation(city_table):
    dc1 = city_table.dict_column("city")
    assert city_table.dict_column("city") is dc1          # cached
    v0 = city_table.version
    city_table.set_column("city", city_table["city"][::-1].copy())
    assert city_table.version == v0 + 1                   # versioned write
    dc2 = city_table.dict_column("city")
    assert dc2 is not dc1                                 # rebuilt
    np.testing.assert_array_equal(dc2.decode(), city_table["city"])


def test_dict_rebind_idiom_invalidates(city_table):
    dc1 = city_table.dict_column("city")
    city_table.columns["city"] = city_table["city"][::-1].copy()
    dc2 = city_table.dict_column("city")                  # identity change
    assert dc2 is not dc1
    np.testing.assert_array_equal(dc2.decode(), city_table["city"])


def test_stats_detect_rebound_string_column():
    # regression: stats() must not serve the old distribution after the
    # documented `table.columns[name] = arr` rebinding idiom
    t = Table({"s": np.array(["a", "a", "a", "b"])})
    atom = Atom("s", "eq", "a", selectivity=0.5)
    assert abs(t.estimate_selectivity(atom) - 0.75) < 1e-6
    t.columns["s"] = np.array(["b", "b", "b", "a"])
    assert abs(t.estimate_selectivity(atom) - 0.25) < 1e-6


def test_column_data_resolves_code_columns(city_table):
    dc = city_table.dict_column("city")
    np.testing.assert_array_equal(city_table.column_data(code_column("city")),
                                  dc.codes)
    # plain columns resolve to themselves; unknown names raise
    assert city_table.column_data("x") is city_table.columns["x"]
    with pytest.raises(KeyError):
        city_table.column_data(code_column("nope"))
    assert decode_column(code_column("city")) == "city"
    assert decode_column("city") is None


# -- codes_expression --------------------------------------------------------

def _mask_cases():
    return [
        np.array(m, dtype=bool) for m in (
            [1, 0, 0, 0, 0],      # single value -> eq
            [1, 1, 0, 0, 0],      # prefix run -> lt
            [0, 0, 0, 1, 1],      # suffix run -> ge
            [0, 1, 1, 0, 0],      # interior run -> ge & le
            [1, 0, 1, 1, 1],      # single gap -> ne/anti-range
            [1, 0, 1, 0, 1],      # fragmented both ways
            [1, 1, 1, 1, 1],      # always true
            [0, 0, 0, 0, 0],      # always false
        )
    ]


@pytest.mark.parametrize("hits", _mask_cases(),
                         ids=lambda h: "".join(str(int(x)) for x in h))
def test_codes_expression_mask_equivalence(hits):
    atom = Atom("city", "eq", "whatever", selectivity=0.5)
    expr = codes_expression(atom, hits)
    assert expr is not None
    codes = np.arange(len(hits), dtype=np.int32)
    np.testing.assert_array_equal(eval_code_expr(expr, codes), hits)
    # and on a realistic repeated-codes vector
    rep = np.repeat(codes, 3)
    np.testing.assert_array_equal(eval_code_expr(expr, rep), hits[rep])


def test_codes_expression_fragmented_mask_becomes_lookup_atom():
    # > MAX_CODE_RUNS runs on both sides -> a single membership atom over
    # the packed code bitmask (the device dict-lookup kernel's shape), NOT
    # a host-fallback bail
    hits = np.array([1, 0] * 6, dtype=bool)
    atom = Atom("city", "eq", "v", selectivity=0.5)
    expr = codes_expression(atom, hits)
    assert isinstance(expr, Atom) and expr.op == "in"
    assert expr.column == code_column("city")
    assert expr.value == tuple(int(c) for c in np.flatnonzero(hits))
    codes = np.arange(len(hits), dtype=np.int32)
    np.testing.assert_array_equal(eval_code_expr(expr, codes), hits)
    # exact selectivity from the code frequencies
    freqs = np.linspace(1, 12, 12)
    freqs = freqs / freqs.sum()
    expr = codes_expression(atom, hits, freqs)
    assert abs(expr.selectivity - freqs[hits].sum()) < 1e-9


def test_codes_expression_exact_selectivities():
    freqs = np.array([0.5, 0.25, 0.125, 0.0625, 0.0625])
    atom = Atom("city", "eq", "v", selectivity=0.9)   # deliberately wrong
    # interior range [1, 3) -> ge 1 (mass 0.5) AND le 2 (mass 0.875)
    expr = codes_expression(atom, np.array([0, 1, 1, 0, 0], bool), freqs)
    assert isinstance(expr, And)
    ge, le = expr.children
    assert ge.op == "ge" and abs(ge.selectivity - 0.5) < 1e-9
    assert le.op == "le" and abs(le.selectivity - 0.875) < 1e-9
    # single value -> eq carrying the value's exact frequency
    eq = codes_expression(atom, np.array([0, 1, 0, 0, 0], bool), freqs)
    assert eq.op == "eq" and abs(eq.selectivity - 0.25) < 1e-9


# -- rewrite_string_atoms ----------------------------------------------------

def test_rewrite_returns_same_tree_when_nothing_rewrites(city_table):
    tree = normalize(And([Atom("x", "lt", 0.0, selectivity=0.5),
                          Atom("x", "gt", -1.0, selectivity=0.5)]))
    assert rewrite_string_atoms(tree, city_table) is tree


def test_rewrite_does_not_mutate_input_tree(city_table):
    tree = normalize(And([Atom("x", "lt", 0.0, selectivity=0.5),
                          Atom("city", "eq", "oslo", selectivity=0.3)]))
    aids = [a.aid for a in tree.atoms]
    names = [a.column for a in tree.atoms]
    out = rewrite_string_atoms(tree, city_table)
    assert out is not tree
    assert [a.aid for a in tree.atoms] == aids
    assert [a.column for a in tree.atoms] == names
    assert any(decode_column(a.column) == "city" for a in out.atoms)


def test_rewrite_skips_udf_atoms(city_table):
    udf = Atom("city", "udf", fn=lambda v: v == "oslo", selectivity=0.3)
    tree = normalize(And([Atom("x", "lt", 0.0, selectivity=0.5), udf]))
    out = rewrite_string_atoms(tree, city_table)
    assert all(decode_column(a.column) is None for a in out.atoms)


def test_rewrite_mask_matches_oracle_all_ops(city_table):
    cases = [
        Atom("city", "eq", "oslo"),
        Atom("city", "eq", "nowhere"),            # always-false atom
        Atom("city", "ne", "tromso"),
        Atom("city", "in", ("bergen", "oslo", "trondheim")),
        Atom("city", "not_in", ("stavanger",)),
        Atom("city", "lt", "stavanger"),
        Atom("city", "le", "oslo"),
        Atom("city", "gt", "bergen"),
        Atom("city", "ge", "tromso"),
        Atom("city", "like", "tr%"),
        Atom("city", "like", "TRO%"),             # case-insensitive LIKE
        Atom("city", "not_like", "%heim"),
        Atom("city", "like", "%o%"),              # non-prefix pattern
    ]
    for atom in cases:
        tree = normalize(And([atom, Atom("x", "lt", 10.0, selectivity=0.9)]))
        out = rewrite_string_atoms(tree, city_table)
        assert all(a.column != "city" for a in out.atoms), repr(atom)
        want = _apply_op(atom, city_table["city"]) & (city_table["x"] < 10.0)
        got = eval_code_expr_tree(out, city_table)
        np.testing.assert_array_equal(got, want, err_msg=repr(atom))


def eval_code_expr_tree(tree, table):
    """Oracle-evaluate a rewritten tree against the table (resolving code
    columns through column_data)."""
    def ev(node):
        if isinstance(node, Atom):
            return _apply_op(node, table.column_data(node.column))
        combine = np.logical_and if isinstance(node, And) else np.logical_or
        out = None
        for c in node.children:
            m = ev(c)
            out = m if out is None else combine(out, m)
        return out
    return ev(tree.root)


# -- hypothesis property tests -----------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    words = st.text(alphabet="abcdxyz", min_size=1, max_size=6)

    @given(st.lists(words, min_size=1, max_size=40),
           st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=60, deadline=None)
    def test_dict_round_trip_property(vocab, seed):
        rng = np.random.default_rng(seed)
        col = np.asarray(rng.choice(np.asarray(vocab, dtype="U8"), 64))
        dc = build_dict_column(col)
        np.testing.assert_array_equal(dc.decode(), col)
        assert np.all(dc.values[:-1] < dc.values[1:])  # strictly sorted
        assert dc.codes.min() >= 0 and dc.codes.max() < dc.n

    @given(st.lists(words, min_size=2, max_size=25, unique=True),
           st.data())
    @settings(max_examples=80, deadline=None)
    def test_rewritten_mask_equals_oracle_property(vocab, data):
        """The rewrite is semantics-preserving for every drawable atom."""
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
        col = np.asarray(rng.choice(np.asarray(vocab, dtype="U8"), 128))
        table = Table({"s": col})
        op = data.draw(st.sampled_from(
            ["eq", "ne", "in", "not_in", "lt", "le", "gt", "ge",
             "like", "not_like"]))
        if op in ("in", "not_in"):
            value = tuple(data.draw(
                st.lists(st.sampled_from(vocab), min_size=1, max_size=4,
                         unique=True)))
        elif op in ("like", "not_like"):
            base = data.draw(st.sampled_from(vocab))
            value = base[: data.draw(st.integers(1, len(base)))] + "%"
        else:
            value = data.draw(st.sampled_from(vocab))
        atom = Atom("s", op, value, selectivity=0.5)
        want = _apply_op(atom, col)
        tree = normalize(And([atom, Atom("s", "ne", "\x00zzz",
                                         selectivity=0.999)]))
        out = rewrite_string_atoms(tree, table)
        got = eval_code_expr_tree(out, table)
        np.testing.assert_array_equal(got, want)
