"""Serving-loop hardening: background drainer, lanes, backpressure,
lifecycle, warm restarts, and the concurrency stress contract.

The stress tests are the PR's acceptance backstop: threads hammer both
lanes while others append and tombstone rows under injected faults, and
at the end every future must be resolved (none lost, none deadlocked)
with a bitmap bit-identical to a numpy-oracle replay of its recorded
snapshot — prefix rows + stamped live mask reproduce any drain's world
because appends only extend and tombstones only mask.
"""
import threading
import time

import numpy as np
import pytest

from repro.columnar import (DrainPolicy, LatencyWindow, StreamBackpressure,
                            StreamClosed, StreamQueryError, StreamSession,
                            Table, make_forest_table, random_tree, run_query)
from repro.core import Atom
from repro.runtime import faults


@pytest.fixture(autouse=True)
def _clean_plane():
    faults.fault_plane().clear()
    yield
    faults.fault_plane().clear()


def _table(n=4000, seed=7):
    return make_forest_table(n, n_dup=1, seed=seed)


def _wait(pred, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return pred()


# -- LatencyWindow ------------------------------------------------------------

def test_latency_window_percentiles():
    w = LatencyWindow(capacity=100)
    for v in range(1, 101):
        w.add(float(v))
    assert w.p50 == 50.0
    assert w.p99 == 99.0
    assert w.percentile(100.0) == 100.0
    assert w.count == 100


def test_latency_window_ring_wraps():
    w = LatencyWindow(capacity=4)
    for v in [1.0, 2.0, 3.0, 4.0, 100.0, 200.0]:
        w.add(v)
    assert w.count == 6
    assert w.percentile(100.0) == 200.0
    assert w.percentile(0.0) == 3.0         # 1.0 and 2.0 were overwritten
    assert LatencyWindow().p99 == 0.0       # empty window reads as zero


# -- background drainer -------------------------------------------------------

def test_background_drain_on_deadline():
    # nobody calls result()/drain(): the deadline alone must resolve it
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=64, background=True,
                       policy=DrainPolicy(max_wait_ms=30,
                                          interactive_wait_ms=5)) as s:
        fut = s.submit(Atom("elevation_0", "lt", 3000.0))
        assert _wait(fut.done)
        assert s.stats.batches == 1
        assert s.stats.latency.count == 1
        assert s.stats.latency_p99_ms >= 25.0   # waited out the deadline


def test_interactive_preempts_bulk():
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=100, background=True,
                       policy=DrainPolicy(max_wait_ms=10_000.0,
                                          interactive_wait_ms=5)) as s:
        bulk = s.submit(Atom("elevation_0", "lt", 3000.0))
        inter = s.submit(Atom("slope_0", "lt", 20.0), lane="interactive")
        assert _wait(inter.done)
        # the interactive drain excluded the still-accumulating bulk lane
        assert not bulk.done()
        assert s.pending_by_lane == {"interactive": 0, "bulk": 1}
        s.drain()                               # manual flush picks it up
        assert bulk.done()


def test_bulk_deadline_carries_interactive_along():
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=100, background=True,
                       policy=DrainPolicy(max_wait_ms=40,
                                          interactive_wait_ms=10_000.0)) as s:
        inter = s.submit(Atom("slope_0", "lt", 20.0), lane="interactive")
        bulk = s.submit(Atom("elevation_0", "lt", 3000.0))
        assert _wait(lambda: bulk.done() and inter.done())
        assert s.stats.batches == 1             # one combined drain


def test_starvation_valve_decision():
    """The fairness valve, unit-tested on fabricated lane state:
    interactive preemption is strict (it beats even a past-deadline
    bulk) until bulk's oldest admit ages past the starvation ceiling,
    where the valve force-drains both lanes and counts the firing."""
    from repro.columnar.drainer import LANES, BackgroundDrainer
    from repro.columnar.stream import StreamFuture, _Pending

    t = _table(500)
    s = StreamSession(t, engine="numpy", max_pending=64)
    pol = DrainPolicy(max_wait_ms=50, interactive_wait_ms=5,
                      starvation_factor=4.0)
    d = BackgroundDrainer(s, pol)       # never started: decision only
    atom = Atom("elevation_0", "lt", 3000.0)
    now = time.perf_counter()

    def pend(lane, age):
        return [_Pending(atom, StreamFuture(s, lane), now - age)]

    # interactive due, bulk young -> interactive alone
    s._lanes["interactive"] = pend("interactive", 0.01)
    s._lanes["bulk"] = pend("bulk", 0.01)
    assert d._due_lanes_locked(now) == ("interactive",)
    # bulk past its OWN deadline still loses to a due interactive
    s._lanes["bulk"] = pend("bulk", pol.wait_s("bulk") + 0.02)
    assert d._due_lanes_locked(now) == ("interactive",)
    assert d.bulk_force_drains == 0
    # ... until the starvation ceiling: valve fires, both lanes drain
    s._lanes["bulk"] = pend("bulk", pol.starvation_s() + 0.01)
    assert d._due_lanes_locked(now) == LANES
    assert d.bulk_force_drains == 1
    # interactive idle: bulk drains on its own deadline as before
    s._lanes["interactive"] = []
    assert d._due_lanes_locked(now) == LANES
    s._lanes["bulk"] = pend("bulk", 0.01)
    assert d._due_lanes_locked(now) == ()
    s._lanes["bulk"] = []
    s.close()


def test_starvation_valve_bounds_bulk_latency_under_flood():
    """Live stress: threads flood the interactive lane so every drainer
    wakeup sees interactive due; a bulk query must still resolve within
    the valve ceiling, and the ``bulk_starved_s`` gauge must have
    surfaced a nonzero age while bulk sat out interactive drains."""
    t = _table(2000)
    pol = DrainPolicy(max_wait_ms=60, interactive_wait_ms=1,
                      starvation_factor=3.0)
    stop = threading.Event()
    starved_seen = [0.0]
    with StreamSession(t, engine="numpy", max_pending=10_000,
                       max_queue=20_000, background=True,
                       policy=pol) as s:

        def flood():
            while not stop.is_set():
                try:
                    s.submit(Atom("slope_0", "lt", 20.0),
                             lane="interactive")
                except StreamClosed:
                    return
                time.sleep(0.0002)

        threads = [threading.Thread(target=flood) for _ in range(2)]
        for th in threads:
            th.start()
        try:
            time.sleep(0.05)            # flood established
            t0 = time.perf_counter()
            bulk = s.submit(Atom("elevation_0", "lt", 3000.0))

            def poll():
                starved_seen[0] = max(starved_seen[0],
                                      s.health()["bulk_starved_s"])
                return bulk.done()

            assert _wait(poll, timeout=10.0)
            waited = time.perf_counter() - t0
        finally:
            stop.set()
            for th in threads:
                th.join()
        # ceiling (0.18s) plus generous scheduling/drain slack
        assert waited < 5 * pol.starvation_s() + 1.0
        # bulk sat out at least one interactive-only drain
        assert starved_seen[0] > 0.0
        from repro.columnar import unpack_bits
        got = unpack_bits(bulk.result(), t.n_records)
        np.testing.assert_array_equal(
            got, t.eval_atom(Atom("elevation_0", "lt", 3000.0)))


def test_max_pending_triggers_immediate_background_drain():
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=4, background=True,
                       policy=DrainPolicy(max_wait_ms=10_000.0,
                                          interactive_wait_ms=10_000.0)) as s:
        futs = [s.submit(Atom("elevation_0", "lt", 3000.0))
                for _ in range(4)]
        assert _wait(lambda: all(f.done() for f in futs))


def test_result_waits_instead_of_draining_under_drainer():
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=64, background=True,
                       policy=DrainPolicy(max_wait_ms=80,
                                          interactive_wait_ms=80)) as s:
        fut = s.submit(Atom("elevation_0", "lt", 3000.0))
        with pytest.raises(TimeoutError):
            fut.result(timeout=0.005)           # deadline not reached yet
        res = fut.result(timeout=5.0)           # the drainer resolves it
        assert res is not None and s.stats.batches == 1


# -- bounded admission --------------------------------------------------------

def test_backpressure_raises_past_max_queue():
    t = _table()
    s = StreamSession(t, engine="numpy", max_pending=4, background=True,
                      max_queue=4, overflow="raise",
                      policy=DrainPolicy(max_wait_ms=10_000.0,
                                         interactive_wait_ms=10_000.0))
    try:
        with s._drain_lock:                     # pin the drainer mid-cycle
            for _ in range(4):
                s.submit(Atom("elevation_0", "lt", 3000.0))
            with pytest.raises(StreamBackpressure):
                s.submit(Atom("elevation_0", "lt", 3000.0))
            assert s.stats.backpressure_rejects == 1
    finally:
        s.close()


def test_backpressure_blocks_until_drain():
    t = _table()
    s = StreamSession(t, engine="numpy", max_pending=4, background=True,
                      max_queue=4, overflow="block",
                      policy=DrainPolicy(max_wait_ms=1.0,
                                         interactive_wait_ms=1.0))
    blocked_fut = []
    try:
        s._drain_lock.acquire()
        held = True
        try:
            for _ in range(4):
                s.submit(Atom("elevation_0", "lt", 3000.0))

            def overflow_submit():
                blocked_fut.append(
                    s.submit(Atom("slope_0", "lt", 20.0)))

            th = threading.Thread(target=overflow_submit)
            th.start()
            th.join(timeout=0.15)
            assert th.is_alive()                # held back, not dropped
            assert s.stats.backpressure_waits == 1
            s._drain_lock.release()
            held = False
            th.join(timeout=5.0)
            assert not th.is_alive()
        finally:
            if held:
                s._drain_lock.release()
        assert _wait(lambda: blocked_fut and blocked_fut[0].done())
    finally:
        s.close()


def test_close_wakes_blocked_submitter():
    t = _table()
    s = StreamSession(t, engine="numpy", max_pending=4, background=True,
                      max_queue=4, overflow="block",
                      policy=DrainPolicy(max_wait_ms=10_000.0,
                                         interactive_wait_ms=10_000.0))
    outcome = []
    s._drain_lock.acquire()
    try:
        admitted = [s.submit(Atom("elevation_0", "lt", 3000.0))
                    for _ in range(4)]

        def overflow_submit():
            try:
                s.submit(Atom("slope_0", "lt", 20.0))
                outcome.append("admitted")
            except StreamClosed:
                outcome.append("closed")

        th = threading.Thread(target=overflow_submit)
        th.start()
        time.sleep(0.05)
        closer = threading.Thread(target=s.close)
        closer.start()
        th.join(timeout=5.0)
        assert not th.is_alive() and outcome == ["closed"]
    finally:
        s._drain_lock.release()
    # close still drains the queries admitted before it
    assert _wait(lambda: all(f.done() for f in admitted))


# -- lifecycle ----------------------------------------------------------------

def test_close_idempotent_and_drains_pending():
    t = _table()
    s = StreamSession(t, engine="numpy", max_pending=64)
    fut = s.submit(Atom("elevation_0", "lt", 3000.0))
    res = s.close()
    assert fut.done() and res is not None and res.stats.n_queries == 1
    assert s.close() is res                     # idempotent
    for call in (lambda: s.submit(Atom("slope_0", "lt", 20.0)),
                 lambda: s.append({}), lambda: s.delete([0])):
        with pytest.raises(StreamClosed):
            call()


def test_context_manager_closes_and_stops_drainer():
    t = _table()
    with StreamSession(t, engine="numpy", max_pending=64,
                       background=True) as s:
        fut = s.submit(Atom("elevation_0", "lt", 3000.0))
        drainer = s._drainer
    assert s.closed and fut.done()
    assert not drainer.running


# -- warm restarts ------------------------------------------------------------

def test_warm_restart_reloads_plans_and_tapes(tmp_path):
    cache_dir = str(tmp_path / "warm")
    t1 = _table(seed=3)
    trees = [random_tree(t1, 5, 3, np.random.default_rng(i))
             for i in range(3)]
    # batched="auto" on the tape engine: per-query compiled tapes (the
    # persistable artifact), still one bundled host sync per drain
    s1 = StreamSession(t1, engine="tape", batched="auto", block=2048,
                       max_pending=64, cache_dir=cache_dir)
    futs = [s1.submit(tr) for tr in trees]
    s1.drain()
    baseline = [f.result() for f in futs]
    assert s1.stats.tape_cache_hits == 0        # cold: everything compiled
    s1.close()

    # "restart": identical data, brand-new process-level state
    t2 = _table(seed=3)
    trees2 = [random_tree(t2, 5, 3, np.random.default_rng(i))
              for i in range(3)]
    s2 = StreamSession(t2, engine="tape", batched="auto", block=2048,
                       max_pending=64, cache_dir=cache_dir)
    assert s2.restore_info["plans"] >= 3
    assert s2.restore_info.get("feedback_keys", 0) > 0
    futs2 = [s2.submit(tr) for tr in trees2]
    res = s2.drain()
    assert res.stats.tape_cache_hits >= 3       # rebound, not recompiled
    assert res.stats.plan_cache_hits >= 3
    for f, base in zip(futs2, baseline):
        np.testing.assert_array_equal(f.result(), base)
    s2.close()


def test_warm_restart_corrupt_cache_cold_starts(tmp_path):
    from repro.columnar import persist
    cache_dir = tmp_path / "warm"
    cache_dir.mkdir()
    (cache_dir / persist.PLAN_CACHE_FILE).write_bytes(b"not a pickle")
    (cache_dir / persist.FEEDBACK_FILE).write_bytes(b"\x80garbage")
    t = _table()
    s = StreamSession(t, engine="numpy", max_pending=64,
                      cache_dir=str(cache_dir))
    assert s.restore_info["plans"] == 0         # degraded to cold start
    fut = s.submit(Atom("elevation_0", "lt", 3000.0))
    s.drain()
    assert fut.done()
    s.close()                                   # flush overwrites the junk
    s3 = StreamSession(_table(), engine="numpy", max_pending=64,
                       cache_dir=str(cache_dir))
    assert s3.restore_info["plans"] >= 1
    s3.close()


# -- concurrency stress (the acceptance backstop) -----------------------------

def _replay_oracle(table, tree, snapshot):
    """Numpy-oracle replay of one future: evaluate over the first
    ``n_records`` rows (append-only prefix == drain-time data) and apply
    the stamped live mask."""
    n, live_words = snapshot
    sub = Table({name: col[:n] for name, col in table.columns.items()})
    res, _, _ = run_query(tree, sub, planner="deepfish", engine="numpy")
    return res if live_words is None else res & live_words


def _run_stress(stream, table, *, n_submitters, per_thread, n_appends,
                n_deletes, poison_every=0):
    resolved = []               # (tree, future) — thread-safe via append
    poisoned = []
    stop = threading.Event()

    def submitter(tid):
        rng = np.random.default_rng(1000 + tid)
        for i in range(per_thread):
            lane = "interactive" if rng.random() < 0.4 else "bulk"
            if poison_every and i % poison_every == poison_every - 1:
                poisoned.append(
                    stream.submit(Atom("no_such_column", "lt", 1.0), lane))
            else:
                tree = random_tree(table, 4, 2, rng)
                resolved.append((tree, stream.submit(tree, lane)))
            if rng.random() < 0.3:
                time.sleep(0.001)

    def appender():
        for i in range(n_appends):
            if stop.is_set():
                return
            extra = make_forest_table(256, n_dup=1, seed=100 + i)
            stream.append({name: extra.columns[name]
                           for name in table.columns})
            time.sleep(0.002)

    def deleter():
        rng = np.random.default_rng(88)
        for _ in range(n_deletes):
            if stop.is_set():
                return
            n = table.n_records
            stream.delete(rng.integers(0, n, size=16))
            time.sleep(0.003)

    threads = [threading.Thread(target=submitter, args=(tid,))
               for tid in range(n_submitters)]
    threads += [threading.Thread(target=appender),
                threading.Thread(target=deleter)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60.0)
    stop.set()
    assert not any(th.is_alive() for th in threads)     # no deadlock
    stream.close()
    return resolved, poisoned


def test_stress_concurrent_lanes_appends_deletes_with_faults():
    t = _table(n=3000, seed=13)
    stream = StreamSession(
        t, engine="numpy", max_pending=16, background=True,
        max_queue=48, overflow="block", retry_backoff_s=0.001,
        policy=DrainPolicy(max_wait_ms=10, interactive_wait_ms=2))
    # a storm of transient faults early in the run exercises the retry
    # rung under concurrency (site matching makes engine irrelevant)
    faults.fault_plane().arm("query.plan", exc=faults.TransientFault,
                             times=3)
    resolved, poisoned = _run_stress(
        stream, t, n_submitters=3, per_thread=30, n_appends=8,
        n_deletes=8, poison_every=10)

    # zero lost futures: everything admitted is resolved or failed
    assert all(f.done() for _, f in resolved)
    assert all(f.done() for f in poisoned)
    for f in poisoned:
        with pytest.raises(StreamQueryError):
            f.result()
    # every successful bitmap is bit-identical to the numpy-oracle
    # replay of its drain-time snapshot
    for tree, f in resolved:
        np.testing.assert_array_equal(
            f.result(), _replay_oracle(t, tree, f.snapshot))
    st = stream.stats
    assert st.submitted == 3 * 30 and st.failed == len(poisoned)
    assert st.completed == len(resolved)
    assert st.retries >= 1
    assert st.quarantined_queries >= len(poisoned)
    assert st.latency.count == len(resolved)


def test_stress_device_engine_degrades_under_faults():
    t = _table(n=3000, seed=17)
    stream = StreamSession(
        t, engine="tape", block=1024, max_pending=8, background=True,
        max_queue=32, overflow="block", retry_backoff_s=0.001,
        policy=DrainPolicy(max_wait_ms=15, interactive_wait_ms=3))
    faults.fault_plane().arm("device.dispatch", exc=faults.DeviceFault,
                             times=2)
    faults.fault_plane().arm("device.dispatch", exc=faults.TransientFault,
                             times=1)
    resolved, _ = _run_stress(
        stream, t, n_submitters=2, per_thread=10, n_appends=4, n_deletes=4)
    assert all(f.done() for _, f in resolved)
    for tree, f in resolved:
        np.testing.assert_array_equal(
            f.result(), _replay_oracle(t, tree, f.snapshot))
    assert stream.stats.degraded_batches >= 1   # the injected OOMs landed
    assert stream.stats.failed == 0
