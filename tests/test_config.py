"""ExecConfig: the unified construction path for every execution surface.

* config-equivalence sweep — every legacy kwarg spelling is bit-identical
  to its ``ExecConfig`` spelling across engines, for ``run_query``,
  ``QuerySession``, and ``StreamSession``,
* invalid combinations raise ONE error type (:class:`ConfigError`, a
  ``ValueError`` subclass) from one validation point,
* the deprecation shim warns exactly once per legacy kwarg name.
"""
import warnings

import numpy as np
import pytest

from repro.columnar import (BitmapBackend, ConfigError, DeviceTapeBackend,
                            ExecConfig, QuerySession, StreamSession,
                            make_forest_table, resolve_backend, run_query)
from repro.columnar.config import (ENGINE_NAMES, PLANNER_NAMES, UNSET,
                                   config_from_kwargs,
                                   reset_legacy_warnings)
from repro.columnar.queries import random_tree


@pytest.fixture(scope="module")
def table():
    return make_forest_table(12_000, n_dup=2, seed=3)


@pytest.fixture(scope="module")
def trees(table):
    return [random_tree(table, 6, 3, np.random.default_rng(s))
            for s in (1, 2, 5)]


def _quiet(fn, *a, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **kw)


# ---------------------------------------------------------------------------
# equivalence: legacy kwargs == ExecConfig spelling, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["numpy", "jax", "tape"])
def test_run_query_legacy_equals_config(table, trees, engine):
    for tree in trees:
        legacy, _, _ = _quiet(run_query, tree, table, planner="deepfish",
                              engine=engine, rewrite_strings=True)
        cfg, _, _ = run_query(tree, table, config=ExecConfig(
            planner="deepfish", engine=engine, rewrite_strings=True))
        assert np.array_equal(legacy, cfg)


@pytest.mark.parametrize("engine", ["numpy", "jax", "tape"])
def test_session_legacy_equals_config(table, trees, engine):
    legacy_sess = _quiet(QuerySession, table, planner="deepfish",
                         engine=engine, block=4096, zone_prune=False,
                         share_margin=None, persist_atom_cache=False)
    config_sess = QuerySession(table, config=ExecConfig(
        planner="deepfish", engine=engine, block=4096, zone_prune=False,
        share_margin=None, persist_atom_cache=False))
    a = legacy_sess.execute(trees)
    b = config_sess.execute(trees)
    for x, y in zip(a.bitmaps, b.bitmaps):
        assert np.array_equal(x, y)


def test_stream_legacy_equals_config(table, trees):
    legacy = _quiet(StreamSession, table, planner="deepfish",
                    engine="tape", batched=True, share_threshold=3)
    config = StreamSession(table, config=StreamSession.DEFAULT_CONFIG
                           .replace(share_threshold=3))
    try:
        fa = [legacy.submit(tr) for tr in trees]
        legacy.drain()
        fb = [config.submit(tr) for tr in trees]
        config.drain()
        for x, y in zip(fa, fb):
            assert np.array_equal(x.result(), y.result())
        assert legacy.session.share_threshold == 3
        assert config.session.share_threshold == 3
    finally:
        legacy.close()
        config.close()


def test_defaults_match_legacy_defaults(table, trees):
    a = QuerySession(table).execute(trees)
    b = QuerySession(table, config=ExecConfig()).execute(trees)
    for x, y in zip(a.bitmaps, b.bitmaps):
        assert np.array_equal(x, y)


def test_session_mirrors_config_attributes(table):
    cfg = ExecConfig(planner="auto", engine="tape", block=4096,
                     share_threshold=4, feedback=False)
    s = QuerySession(table, config=cfg)
    assert s.config is cfg
    assert (s.planner, s.engine, s.block) == ("auto", "tape", 4096)
    assert s.share_threshold == 4 and s.feedback is None


# ---------------------------------------------------------------------------
# one error type, one validation point
# ---------------------------------------------------------------------------

def test_config_error_is_valueerror():
    assert issubclass(ConfigError, ValueError)


@pytest.mark.parametrize("kwargs", [
    {"planner": "bogus"},
    {"engine": "bogus"},
    {"block": 100},                       # not a multiple of 32
    {"block": 0},
    {"batched": "sometimes"},
    {"share_threshold": 0},
    {"shards": 3},                        # not a power of two
    {"shards": 0},
    {"engine": "numpy", "shards": 2},     # host engine cannot shard
    {"engine": "jax", "shards": 2},
    {"engine": "pallas", "shards": 2},
    {"engine": "tape-pallas", "shards": 2},
])
def test_invalid_configs_rejected(kwargs):
    with pytest.raises(ConfigError):
        ExecConfig(**kwargs)


def test_legacy_spellings_raise_same_type(table):
    with pytest.raises(ConfigError):
        _quiet(QuerySession, table, planner="bogus")
    with pytest.raises(ConfigError):     # was KeyError before the redesign
        _quiet(run_query, None, table, planner="bogus")
    with pytest.raises(ConfigError):
        _quiet(StreamSession, table, engine="bogus")


def test_config_plus_legacy_kwarg_rejected(table):
    with pytest.raises(ConfigError):
        QuerySession(table, planner="deepfish", config=ExecConfig())
    with pytest.raises(ConfigError):
        _quiet(run_query, None, table, engine="tape", config=ExecConfig())


def test_backend_mismatches_rejected(table, trees):
    tape_be = resolve_backend(table, ExecConfig(engine="tape"))
    numpy_be = resolve_backend(table, ExecConfig(engine="numpy"))
    assert isinstance(tape_be, DeviceTapeBackend)
    assert isinstance(numpy_be, BitmapBackend)
    with pytest.raises(ConfigError):     # tape engine + BitmapBackend
        run_query(trees[0], table, config=ExecConfig(engine="tape"),
                  backend=numpy_be)
    with pytest.raises(ConfigError):     # numpy engine + DeviceTapeBackend
        run_query(trees[0], table, config=ExecConfig(engine="numpy"),
                  backend=tape_be)
    with pytest.raises(ConfigError):     # sharded config + unsharded reuse
        resolve_backend(table, ExecConfig(engine="tape", shards=2),
                        reuse=tape_be)
    other = make_forest_table(1_000, n_dup=2, seed=9)
    with pytest.raises(ConfigError):     # table identity
        resolve_backend(other, ExecConfig(engine="tape"), reuse=tape_be)


def test_resolve_backend_reuses_matching(table):
    cfg = ExecConfig(engine="tape")
    be = resolve_backend(table, cfg)
    assert resolve_backend(table, cfg, reuse=be) is be


def test_stream_typo_kwarg_is_typeerror(table):
    # the blind **session_kwargs passthrough is gone
    with pytest.raises(TypeError):
        StreamSession(table, sare_margin=2.0)


# ---------------------------------------------------------------------------
# deprecation shim: exactly one warning per kwarg name
# ---------------------------------------------------------------------------

def test_deprecation_warns_exactly_once_per_kwarg(table):
    reset_legacy_warnings()
    try:
        with warnings.catch_warnings(record=True) as seen:
            warnings.simplefilter("always")
            QuerySession(table, planner="deepfish", engine="numpy")
            QuerySession(table, planner="shallowfish", engine="jax")
            run_query(random_tree(table, 4, 2, np.random.default_rng(0)),
                      table, planner="deepfish", engine="numpy")
        deps = [w for w in seen if issubclass(w.category,
                                              DeprecationWarning)]
        names = sorted(str(w.message).split("=")[0] for w in deps)
        assert names == ["engine", "planner"]
    finally:
        reset_legacy_warnings()


def test_config_from_kwargs_defaults_and_unset():
    base = ExecConfig(engine="tape", batched=True)
    assert config_from_kwargs(None, defaults=base) is base
    reset_legacy_warnings()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            got = config_from_kwargs(None, defaults=base, planner="auto",
                                     engine=UNSET)
        assert got.planner == "auto" and got.engine == "tape"
    finally:
        reset_legacy_warnings()


def test_name_tables_cover_all_surfaces():
    assert set(QuerySession._ENGINES) == set(ENGINE_NAMES)
    assert "auto" in PLANNER_NAMES
