"""Pallas kernel sweeps vs the pure-jnp ref oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.columnar.bitmap import pack_bits, popcount, unpack_bits
from repro.kernels import ops as kops
from repro.kernels import ref as kref

SHAPES = [(1, 256), (3, 1024), (4, 8192), (7, 2048)]   # (blocks, block_size)
OPS = list(range(6))


@pytest.mark.parametrize("n,b", SHAPES)
@pytest.mark.parametrize("opcode", OPS)
def test_predicate_kernel_matches_ref(n, b, opcode):
    rng = np.random.default_rng(opcode * 100 + n)
    col = rng.normal(size=(n, b)).astype(np.float32)
    bits = rng.integers(0, 2 ** 32, size=(n, b // 32), dtype=np.uint32)
    if n > 1:
        bits[1] = 0                       # dead block exercises pl.when skip
    value = float(rng.normal())
    got = np.asarray(kops.predicate_blocks(jnp.asarray(col),
                                           jnp.asarray(bits), value, opcode,
                                           interpret=True))
    want = np.asarray(kref.predicate_blocks_ref(jnp.asarray(col),
                                                jnp.asarray(bits), value,
                                                opcode))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_predicate_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    col = (rng.normal(size=(2, 512)) * 100).astype(dtype)
    bits = rng.integers(0, 2 ** 32, size=(2, 16), dtype=np.uint32)
    got = np.asarray(kops.predicate_blocks(
        jnp.asarray(col.astype(np.float32)), jnp.asarray(bits), 3.0, 0,
        interpret=True))
    want = np.asarray(kref.predicate_blocks_ref(
        jnp.asarray(col.astype(np.float32)), jnp.asarray(bits), 3.0, 0))
    np.testing.assert_array_equal(got, want)


def test_predicate_kernel_matches_numpy_oracle():
    """Kernel vs the *numpy* column-store oracle end to end."""
    rng = np.random.default_rng(1)
    n, b = 4, 2048
    col = rng.normal(size=(n * b,)).astype(np.float32)
    mask = rng.random(n * b) < 0.6
    bits = pack_bits(mask).reshape(n, b // 32)
    got = np.asarray(kops.predicate_blocks(
        jnp.asarray(col.reshape(n, b)), jnp.asarray(bits), 0.25, 0,
        interpret=True))
    want_mask = (col < 0.25) & mask
    np.testing.assert_array_equal(unpack_bits(got.reshape(-1), n * b),
                                  want_mask)


@pytest.mark.parametrize("n,w", [(1, 8), (5, 64), (3, 256)])
@pytest.mark.parametrize("opcode", [0, 1, 2])
def test_bitmap_kernel_matches_ref(n, w, opcode):
    rng = np.random.default_rng(opcode + n)
    a = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    b = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    out, pops = kops.bitmap_op(jnp.asarray(a), jnp.asarray(b), opcode,
                               interpret=True)
    ref_fn = [kref.bitmap_and_ref, kref.bitmap_or_ref,
              kref.bitmap_andnot_ref][opcode]
    want = np.asarray(ref_fn(a, b))
    np.testing.assert_array_equal(np.asarray(out), want)
    np.testing.assert_array_equal(
        np.asarray(pops), np.asarray(kref.popcount_ref(jnp.asarray(want))))


def test_pack_unpack_roundtrip_jnp_vs_numpy():
    rng = np.random.default_rng(2)
    mask = rng.random(4096) < 0.37
    np_words = pack_bits(mask)
    j_words = np.asarray(kref.pack_u32(jnp.asarray(mask)))
    np.testing.assert_array_equal(np_words, j_words)
    back = np.asarray(kref.unpack_u32(jnp.asarray(np_words)))
    np.testing.assert_array_equal(back[:4096], mask)
    assert popcount(np_words) == mask.sum()


def test_fused_chain_ref():
    rng = np.random.default_rng(3)
    k, n, b = 3, 2, 512
    cols = rng.normal(size=(k, n, b)).astype(np.float32)
    bits = rng.integers(0, 2 ** 32, size=(n, b // 32), dtype=np.uint32)
    vals = rng.normal(size=(k,)).astype(np.float32)
    got = np.asarray(kref.fused_chain_ref(jnp.asarray(cols),
                                          jnp.asarray(bits),
                                          jnp.asarray(vals), (0, 2, 0),
                                          conj=True))
    m = (cols[0] < vals[0]) & (cols[1] > vals[1]) & (cols[2] < vals[2])
    want = np.asarray(kref.pack_u32(jnp.asarray(
        m & np.asarray(kref.unpack_u32(jnp.asarray(bits))))))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,w", [(2, 16), (4, 64)])
@pytest.mark.parametrize("opcode", [0, 1, 2])
def test_bitmap_setop_kernel_direct(n, w, opcode):
    """bitmap_setop itself (not the jitted wrapper): result + fused pops."""
    from repro.kernels.bitmap_ops import bitmap_setop
    rng = np.random.default_rng(10 * n + opcode)
    a = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    b = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    out, pops = bitmap_setop(jnp.asarray(a), jnp.asarray(b), opcode,
                             interpret=True)
    ref_fn = [kref.bitmap_and_ref, kref.bitmap_or_ref,
              kref.bitmap_andnot_ref][opcode]
    want = np.asarray(ref_fn(a, b))
    np.testing.assert_array_equal(np.asarray(out), want)
    assert pops.shape == (n, 1)
    want_pops = [popcount(row) for row in want]
    np.testing.assert_array_equal(np.asarray(pops)[:, 0], want_pops)


@pytest.mark.parametrize("n,w,k", [(2, 16, 2), (3, 8, 4)])
@pytest.mark.parametrize("conj", [True, False])
def test_fused_chain_scan_kernel_direct(n, w, k, conj):
    """fused_chain_scan itself, pre-layouted bit-major inputs + prefetch
    pops (incl. a dead block exercising the pl.when skip)."""
    from repro.kernels.fused_chain import fused_chain_scan
    rng = np.random.default_rng(n * 7 + k)
    cols_bm = rng.normal(size=(n, k, 32, w)).astype(np.float32)
    bits = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    if n > 1:
        bits[-1] = 0
    pops = np.asarray(kref.popcount_ref(jnp.asarray(bits)), dtype=np.int32)
    vals = rng.normal(size=(k,)).astype(np.float32)
    opcodes = tuple(int(rng.integers(0, 6)) for _ in range(k))
    got = np.asarray(fused_chain_scan(
        jnp.asarray(cols_bm), jnp.asarray(bits), jnp.asarray(pops),
        jnp.asarray(vals), opcodes, conj=conj, interpret=True))
    # oracle on the same bit-major layout
    acc = None
    for i, op in enumerate(opcodes):
        cmp = np.asarray(kref.compare(jnp.asarray(cols_bm[:, i]),
                                      vals[i], op))
        acc = cmp if acc is None else (acc & cmp if conj else acc | cmp)
    bitpos = np.arange(32, dtype=np.uint32)[None, :, None]
    in_set = ((bits[:, None, :] >> bitpos) & 1).astype(bool)
    want = ((acc & in_set).astype(np.uint32) << bitpos).sum(
        axis=1, dtype=np.uint32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,b,k", [(2, 512, 2), (3, 1024, 3), (1, 256, 4)])
@pytest.mark.parametrize("conj", [True, False])
def test_fused_chain_kernel_matches_ref(n, b, k, conj):
    rng = np.random.default_rng(n * 10 + k)
    cols = rng.normal(size=(k, n, b)).astype(np.float32)
    bits = rng.integers(0, 2 ** 32, size=(n, b // 32), dtype=np.uint32)
    if n > 1:
        bits[0] = 0                      # dead block path
    vals = rng.normal(size=(k,)).astype(np.float32)
    opcodes = tuple(int(rng.integers(0, 6)) for _ in range(k))
    got = np.asarray(kops.fused_chain_blocks(
        jnp.asarray(cols), jnp.asarray(bits), vals, opcodes, conj=conj,
        interpret=True))
    want = np.asarray(kref.fused_chain_ref(
        jnp.asarray(cols), jnp.asarray(bits), jnp.asarray(vals), opcodes,
        conj=conj))
    want = np.asarray(want)
    # dead blocks: kernel writes zeros; ref keeps mask-AND (also zeros)
    np.testing.assert_array_equal(got, want)


def _pack_mask(hits):
    """bool[|dict|] -> packed u32[ceil/32] code hit bitmask (the canonical
    packing — masks must follow the same convention as record bitmaps)."""
    return pack_bits(np.asarray(hits, dtype=bool))


@pytest.mark.parametrize("n,b,dict_n", [(1, 256, 7), (3, 1024, 37),
                                        (4, 2048, 64), (2, 512, 200)])
def test_dict_lookup_kernel_matches_ref(n, b, dict_n):
    rng = np.random.default_rng(n * 100 + dict_n)
    col = rng.integers(0, dict_n, size=(n, b)).astype(np.float32)
    bits = rng.integers(0, 2 ** 32, size=(n, b // 32), dtype=np.uint32)
    if n > 1:
        bits[1] = 0                       # dead block exercises pl.when skip
    mask = _pack_mask(rng.random(dict_n) < 0.4)
    got = np.asarray(kops.dict_lookup_blocks(
        jnp.asarray(col), jnp.asarray(bits), jnp.asarray(mask),
        interpret=True))
    want = np.asarray(kref.dict_lookup_ref(
        jnp.asarray(col), jnp.asarray(bits), jnp.asarray(mask)))
    np.testing.assert_array_equal(got, want)


def test_dict_lookup_matches_numpy_oracle():
    """Kernel + ref vs a direct numpy membership test, end to end."""
    rng = np.random.default_rng(5)
    n, b, dict_n = 3, 1024, 23
    codes = rng.integers(0, dict_n, size=n * b)
    live = rng.random(n * b) < 0.7
    hits = rng.random(dict_n) < 0.5
    bits = pack_bits(live).reshape(n, b // 32)
    mask = _pack_mask(hits)
    for fn in (kops.dict_lookup_blocks, kref.dict_lookup_ref):
        kwargs = {"interpret": True} if fn is kops.dict_lookup_blocks else {}
        got = np.asarray(fn(jnp.asarray(codes.reshape(n, b).astype(np.float32)),
                            jnp.asarray(bits), jnp.asarray(mask), **kwargs))
        np.testing.assert_array_equal(
            unpack_bits(got.reshape(-1), n * b), hits[codes] & live)


def test_dict_lookup_multi_matches_single():
    """Q stacked record sets against one code column == Q single calls."""
    from repro.kernels.dict_lookup import (dict_lookup_scan,
                                           dict_lookup_scan_multi)
    rng = np.random.default_rng(9)
    q, n, b, dict_n = 3, 2, 512, 12
    w = b // 32
    col = rng.integers(0, dict_n, size=(n, b)).astype(np.float32)
    col_bm = jnp.asarray(col.reshape(n, w, 32).transpose(0, 2, 1))
    bits = rng.integers(0, 2 ** 32, size=(q, n, w), dtype=np.uint32)
    mask = jnp.asarray(_pack_mask(rng.random(dict_n) < 0.3))
    pops = kref.popcount_ref(jnp.asarray(bits.reshape(q * n, w)))
    multi = np.asarray(dict_lookup_scan_multi(
        col_bm, jnp.asarray(bits.reshape(q * n, w)),
        pops.astype(jnp.int32), mask, interpret=True)).reshape(q, n, w)
    for j in range(q):
        single = np.asarray(dict_lookup_scan(
            col_bm, jnp.asarray(bits[j]),
            kref.popcount_ref(jnp.asarray(bits[j])).astype(jnp.int32),
            mask, interpret=True))
        np.testing.assert_array_equal(multi[j], single)
