"""Streaming admission layer + delta-reuse accounting + tape-cache reuse."""
import numpy as np
import pytest

from repro.columnar import (QuerySession, StreamQueryError, StreamSession,
                            make_forest_table, random_tree, run_query)
from repro.core import Atom
from repro.serve import RequestRouter


def _rows_like(table, n, seed):
    src = make_forest_table(n, n_dup=1, seed=seed)
    return {name: src.columns[name] for name in table.columns}


def _oracle(table, tree):
    return run_query(tree, table, planner="deepfish", engine="numpy")[0]


# -- StreamSession ------------------------------------------------------------

def test_stream_submit_drain_matches_oracle():
    t = make_forest_table(6000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=64)
    rng = np.random.default_rng(1)
    queries = [random_tree(t, 4, 2, rng) for _ in range(5)]
    futs = [stream.submit(q) for q in queries]
    assert not any(f.done() for f in futs)
    assert stream.pending == 5
    res = stream.drain()
    assert res.stats.n_queries == 5 and stream.pending == 0
    for f, q in zip(futs, queries):
        assert f.done()
        np.testing.assert_array_equal(f.result(), _oracle(t, q))
    assert stream.stats.batches == 1 and stream.stats.completed == 5


def test_stream_result_triggers_cooperative_drain():
    t = make_forest_table(3000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=64)
    q = random_tree(t, 4, 2, np.random.default_rng(2))
    fut = stream.submit(q)
    np.testing.assert_array_equal(fut.result(), _oracle(t, q))  # no deadlock
    assert stream.stats.batches == 1


def test_stream_auto_drains_at_max_pending():
    t = make_forest_table(3000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=2)
    rng = np.random.default_rng(3)
    a = stream.submit(random_tree(t, 4, 2, rng))
    assert not a.done()
    b = stream.submit(random_tree(t, 4, 2, rng))
    assert a.done() and b.done()                   # admission hit the cap


def test_stream_snapshot_at_drain_sees_interleaved_appends():
    t = make_forest_table(4000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=64)
    rng = np.random.default_rng(4)
    q1 = random_tree(t, 4, 2, rng)
    f1 = stream.submit(q1)
    stream.append(_rows_like(t, 800, seed=11))     # lands before the drain
    q2 = random_tree(t, 4, 2, rng)
    f2 = stream.submit(q2)
    stream.drain()
    assert t.n_records == 4800
    assert f1.n_records == 4800                    # snapshot at drain time
    np.testing.assert_array_equal(f1.result(), _oracle(t, q1))
    np.testing.assert_array_equal(f2.result(), _oracle(t, q2))
    assert stream.stats.appends == 1 and stream.stats.appended_rows == 800


def test_stream_tape_one_bundled_sync_per_drain():
    t = make_forest_table(8000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="tape", block=4096, max_pending=64)
    rng = np.random.default_rng(5)
    queries = [random_tree(t, 4, 2, rng) for _ in range(4)]
    for q in queries:
        stream.submit(q)
    stream.drain()
    be = stream.session._backend
    assert be.host_syncs == 1                      # one bundled sync
    stream.append(_rows_like(t, 1000, seed=12))
    futs = [stream.submit(q) for q in queries]
    res = stream.drain()
    assert be.host_syncs == 2                      # still one per drain
    for f, q in zip(futs, queries):
        np.testing.assert_array_equal(f.result(), _oracle(t, q))
    # the drain after the append reused the device columns: only the dirty
    # tail re-uploaded, and cached atom results were spliced, not redone
    assert 0 < res.stats.upload_bytes < be.uploaded_bytes
    assert res.stats.atoms_delta_extended > 0
    assert res.stats.delta_reuse_ratio > 0.5


def test_stream_delta_reuse_on_host_engine():
    t = make_forest_table(6000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=64,
                           share_threshold=1)
    rng = np.random.default_rng(6)
    queries = [random_tree(t, 5, 2, rng) for _ in range(3)] * 2
    for q in queries:
        stream.submit(q)
    stream.drain()
    stream.append(_rows_like(t, 600, seed=13))
    futs = [stream.submit(q) for q in queries]
    res = stream.drain()
    s = res.stats
    assert s.atoms_delta_extended > 0
    assert s.delta_rows_evaluated > 0
    assert s.delta_reuse_ratio == pytest.approx(6000 / 6600)
    for f, q in zip(futs, queries):
        np.testing.assert_array_equal(f.result(), _oracle(t, q))


def test_stream_failure_quarantined_to_own_future():
    # a broken query must fail only itself: drain never raises, the bad
    # future carries its own StreamQueryError (original as __cause__),
    # and batch-mates resolve normally
    t = make_forest_table(1000, n_dup=1, seed=7)
    stream = StreamSession(t, engine="numpy", max_pending=64)
    good = stream.submit(Atom("elevation_0", "lt", 3000.0))
    bad = stream.submit(Atom("no_such_column", "lt", 1.0))
    assert stream.drain() is None      # quarantine drains have no result
    assert good.done() and bad.done()
    from repro.columnar import pack_bits
    np.testing.assert_array_equal(
        good.result(), pack_bits(t.columns["elevation_0"] < 3000.0))
    with pytest.raises(StreamQueryError) as ei:
        bad.result()
    assert isinstance(ei.value.__cause__, KeyError)
    other = stream.submit(Atom("still_missing", "lt", 1.0))
    stream.drain()
    with pytest.raises(StreamQueryError) as ei2:
        other.result()
    assert ei2.value is not ei.value   # never a shared exception object
    assert stream.stats.quarantined_queries == 2
    assert stream.stats.failed == 2


# -- plan-cache tape reuse ----------------------------------------------------

def test_tape_cache_rebind_skips_recompiles():
    t = make_forest_table(8000, n_dup=1, seed=7)
    rng = np.random.default_rng(8)
    queries = [random_tree(t, 5, 3, rng) for _ in range(3)]
    sess = QuerySession(t, planner="deepfish", engine="tape", block=4096)
    r1 = sess.execute(queries)
    assert r1.stats.tape_cache_hits == 0           # cold cache: all compiled
    r2 = sess.execute(queries)
    assert r2.stats.tape_cache_hits == len(queries)  # rebound, not recompiled
    assert r2.stats.plan_cache_hits == len(queries)
    for q, bm in zip(queries, r2.bitmaps):
        np.testing.assert_array_equal(bm, _oracle(t, q))


def test_tape_rebind_across_key_equal_trees():
    """A fresh, structurally identical tree must reuse the cached tape and
    still bind its own comparison values."""
    from repro.core import normalize, tree_copy
    t = make_forest_table(8000, n_dup=1, seed=7)
    tree = random_tree(t, 5, 3, np.random.default_rng(9))
    sess = QuerySession(t, planner="deepfish", engine="tape", block=4096)
    sess.execute([tree])
    clone = normalize(tree_copy(tree.root))
    res = sess.execute([clone])
    assert res.stats.tape_cache_hits == 1
    np.testing.assert_array_equal(res.bitmaps[0], _oracle(t, tree))


# -- persistent (streaming) router -------------------------------------------

def test_persistent_router_routes_per_call_batches():
    rng = np.random.default_rng(0)

    def reqs(n):
        return {"tier": rng.choice(3, n).astype(np.int32),
                "tokens": rng.integers(8, 4096, n).astype(np.int32)}

    rules = [
        (Atom("tier", "eq", 2) | Atom("tokens", "lt", 1024)),
        Atom("tokens", "lt", 1024),
    ]
    router = RequestRouter(rules, persistent=True)
    r1 = reqs(64)
    m1 = router.route(r1)
    assert m1.shape == (2, 64)
    np.testing.assert_array_equal(m1[1], r1["tokens"] < 1024)
    r2 = reqs(48)
    m2 = router.route(r2)                          # appends, returns delta
    assert m2.shape == (2, 48)
    np.testing.assert_array_equal(
        m2[0], (r2["tier"] == 2) | (r2["tokens"] < 1024))
    np.testing.assert_array_equal(m2[1], r2["tokens"] < 1024)
    assert router.table.n_records == 112           # history accumulated
    # per-call cost is delta-shaped: cached atoms spliced, not re-evaluated
    assert router.last_result.stats.atoms_delta_extended > 0
