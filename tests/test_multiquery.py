"""Multi-query layer: canonical keys, plan cache, atom dedupe, lockstep."""
import numpy as np
import pytest

from repro.columnar import (BitmapBackend, JaxBlockBackend, LRUPlanCache,
                            QuerySession, bitmap_and, pack_bits, random_tree,
                            run_query)
from repro.core import (And, Atom, Or, PerAtomCostModel, atom_key,
                        canonical_key, execute_plan, normalize, tree_copy)
from repro.serve import RequestRouter


def _tree(sels, shuffle=False):
    """(a & (b | c)) with given atom selectivities, optionally reordered."""
    a = Atom("x0", "lt", 1.0, selectivity=sels[0])
    b = Atom("x1", "lt", 2.0, selectivity=sels[1])
    c = Atom("x2", "lt", 3.0, selectivity=sels[2])
    expr = And([Or([c, b]), a]) if shuffle else And([a, Or([b, c])])
    return normalize(expr)


# -- canonical_key -----------------------------------------------------------

def test_canonical_key_invariant_to_sibling_order():
    t1 = _tree([0.3, 0.5, 0.7])
    t2 = _tree([0.3, 0.5, 0.7], shuffle=True)
    k1, o1 = canonical_key(t1)
    k2, o2 = canonical_key(t2)
    assert k1 == k2
    # canonical order maps positions to equivalent atoms in both trees
    assert [t1.atoms[a].selectivity for a in o1] == \
           [t2.atoms[a].selectivity for a in o2]


def test_canonical_key_quantization_buckets():
    base, _ = canonical_key(_tree([0.50, 0.5, 0.7]), sel_step=0.05)
    same, _ = canonical_key(_tree([0.51, 0.5, 0.7]), sel_step=0.05)
    diff, _ = canonical_key(_tree([0.60, 0.5, 0.7]), sel_step=0.05)
    assert base == same          # drift inside the bucket: same key
    assert base != diff          # drift past the bucket edge: new key


def test_atom_key_identity():
    assert atom_key(Atom("a", "lt", 1.0)) == atom_key(
        Atom("a", "lt", 1.0, selectivity=0.9, cost_factor=3.0))
    assert atom_key(Atom("a", "lt", 1.0)) != atom_key(Atom("a", "le", 1.0))
    assert atom_key(Atom("a", "in", (1, 2))) == atom_key(
        Atom("a", "in", [1, 2]))


# -- plan cache --------------------------------------------------------------

def test_plan_cache_hit_is_bit_identical(forest):
    rng = np.random.default_rng(11)
    tree = random_tree(forest, n_atoms=6, depth=3, rng=rng)
    cache = LRUPlanCache()
    model = PerAtomCostModel()
    p1 = cache.get_or_plan(tree, "deepfish", model, forest.n_records)
    assert cache.stats.misses == 1
    # a structurally identical query (fresh copy, same statistics) must hit
    # and produce the same bitmap as planning from scratch
    tree2 = normalize(tree_copy(tree.root))
    p2 = cache.get_or_plan(tree2, "deepfish", model, forest.n_records)
    assert cache.stats.hits == 1
    r1 = execute_plan(p1, BitmapBackend(forest))
    r2 = execute_plan(p2, BitmapBackend(forest))
    np.testing.assert_array_equal(r1, r2)
    fresh, _, _ = run_query(tree2, forest, planner="deepfish")
    np.testing.assert_array_equal(r2, fresh)


def test_plan_cache_stale_on_selectivity_drift():
    cache = LRUPlanCache(sel_step=0.05)
    model = PerAtomCostModel()
    cache.get_or_plan(_tree([0.50, 0.30, 0.70]), "shallowfish", model)
    cache.get_or_plan(_tree([0.52, 0.30, 0.70]), "shallowfish", model)
    assert cache.stats.hits == 1          # in-bucket drift: cache hit
    cache.get_or_plan(_tree([0.60, 0.30, 0.70]), "shallowfish", model)
    assert cache.stats.misses == 2        # past the bucket: stale, replanned


def test_plan_cache_lru_eviction():
    cache = LRUPlanCache(capacity=2)
    model = PerAtomCostModel()
    for s in (0.1, 0.3, 0.5):
        cache.get_or_plan(_tree([s, 0.4, 0.6]), "shallowfish", model)
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    cache.get_or_plan(_tree([0.1, 0.4, 0.6]), "shallowfish", model)
    assert cache.stats.hits == 0          # oldest entry was evicted


# -- apply_atom_multi --------------------------------------------------------

@pytest.mark.parametrize("engine", ["numpy", "jax", "pallas"])
def test_apply_atom_multi_matches_single(forest, engine):
    rng = np.random.default_rng(4)
    atom = Atom("slope_0", "lt", forest.value_at_selectivity("slope_0", 0.4))
    ds = [pack_bits(rng.random(forest.n_records) < f) for f in (0.2, 0.7, 1.0)]
    if engine == "numpy":
        be = BitmapBackend(forest)
    else:
        be = JaxBlockBackend(forest, engine=engine)
    singles = [be.apply_atom(atom, d) for d in ds]
    n_single = be.stats.atom_applications
    multi = be.apply_atom_multi(atom, ds)
    for s, m in zip(singles, multi):
        np.testing.assert_array_equal(s, m)
    assert be.stats.atom_applications == n_single + 1   # one column touch


# -- batch dedupe ------------------------------------------------------------

def _workload(table, n_queries, n_templates, seed=0):
    rng = np.random.default_rng(seed)
    pool = [random_tree(table, n_atoms=5, depth=3, rng=rng)
            for _ in range(n_templates)]
    return [pool[rng.integers(n_templates)] for _ in range(n_queries)]


def test_batch_dedupe_fewer_atom_applications(forest):
    queries = _workload(forest, 64, 8)
    base_bitmaps, base_applications = [], 0
    for t in queries:
        bm, _, be = run_query(t, forest, planner="deepfish")
        base_bitmaps.append(bm)
        base_applications += be.stats.atom_applications
    session = QuerySession(forest, planner="deepfish", engine="numpy")
    res = session.execute(queries)
    for a, b in zip(base_bitmaps, res.bitmaps):
        np.testing.assert_array_equal(a, b)
    # strictly fewer physical apply_atom calls than 64 independent runs
    assert res.stats.physical_atoms < base_applications
    assert res.stats.physical_atoms == res.backend.stats.atom_applications
    assert res.stats.dedupe_ratio > 1.0
    assert res.stats.plan_cache_hits > 0


def test_lockstep_batches_kernel_invocations(forest):
    queries = _workload(forest, 8, 2, seed=3)
    base = [run_query(t, forest, planner="deepfish", engine="numpy")[0]
            for t in queries]
    session = QuerySession(forest, planner="deepfish", engine="jax",
                           batched=True)
    res = session.execute(queries)
    for a, b in zip(base, res.bitmaps):
        np.testing.assert_array_equal(a, b)
    assert res.stats.kernel_batches >= 1
    assert res.stats.dedupe_ratio > 1.0


def test_share_threshold_disables_sharing(forest):
    queries = _workload(forest, 8, 2, seed=5)
    session = QuerySession(forest, planner="deepfish", engine="numpy",
                           share_threshold=10**9, batched=False)
    res = session.execute(queries)
    # nothing shared: every logical application touched the column
    assert res.stats.shared_atom_keys == 0
    assert res.stats.physical_atoms == res.stats.logical_atoms
    base = [run_query(t, forest, planner="deepfish")[0] for t in queries]
    for a, b in zip(base, res.bitmaps):
        np.testing.assert_array_equal(a, b)


def test_shared_atom_cache_is_bit_exact(forest):
    # one query's atom result ANDed from the full-table cache must equal the
    # gather path even when D is tiny
    atom = Atom("slope_0", "lt", forest.value_at_selectivity("slope_0", 0.3))
    rng = np.random.default_rng(9)
    d = pack_bits(rng.random(forest.n_records) < 0.01)
    be = BitmapBackend(forest)
    want = be.apply_atom(atom, d)
    full = be.apply_atom(atom, be.full())
    np.testing.assert_array_equal(want, bitmap_and(full, d))


# -- serve integration -------------------------------------------------------

def test_router_routes_rule_sets():
    rng = np.random.default_rng(0)
    n = 128
    reqs = {"tier": rng.choice(3, n).astype(np.int32),
            "tokens": rng.integers(8, 4096, n).astype(np.int32),
            "flagged": rng.choice(2, n, p=[.9, .1]).astype(np.int32)}
    rules = [
        (Atom("tier", "eq", 2) | Atom("tokens", "lt", 1024))
        & Atom("flagged", "eq", 0),
        Atom("tier", "eq", 2) & Atom("flagged", "eq", 0),
        Atom("tokens", "lt", 1024),
    ]
    router = RequestRouter(rules)
    routes = router.route(reqs)
    t, p, f = reqs["tier"], reqs["tokens"], reqs["flagged"]
    np.testing.assert_array_equal(routes[0], ((t == 2) | (p < 1024)) & (f == 0))
    np.testing.assert_array_equal(routes[1], (t == 2) & (f == 0))
    np.testing.assert_array_equal(routes[2], p < 1024)
    assert router.last_result.stats.dedupe_ratio > 1.0   # rules share atoms
    # plan cache persists across route calls
    router.route(reqs)
    assert router.last_result.stats.plan_hit_rate == 1.0
    # single-expression admit API unchanged
    admit = RequestRouter(rules[0]).admit(reqs)
    np.testing.assert_array_equal(admit, routes[0])


# -- selective sharing (cost-modeled promotion) -------------------------------

def _shared_atom_workload(forest):
    """Batch where one atom recurs behind highly selective guards: the
    summed expected count(D) of its applications is far below |R|, so the
    |R| full-table touch cannot pay for itself."""
    cheap = Atom("slope_0", "lt",
                 forest.value_at_selectivity("slope_0", 0.05),
                 selectivity=0.05)
    shared = Atom("aspect_0", "lt",
                  forest.value_at_selectivity("aspect_0", 0.5),
                  selectivity=0.5)
    import dataclasses
    trees = []
    for i in range(4):
        g = 0.04 + 0.002 * i          # distinct guard per query
        guard = Atom("elevation_0", "lt",
                     forest.value_at_selectivity("elevation_0", g),
                     selectivity=g)
        trees.append(normalize(
            guard & dataclasses.replace(cheap, aid=-1)
            & dataclasses.replace(shared, aid=-1)))
    return trees


def test_selective_sharing_rejects_unprofitable_promotion(forest):
    queries = _shared_atom_workload(forest)
    sess = QuerySession(forest, planner="deepfish", engine="numpy",
                        batched=False, annotate=False,
                        persist_atom_cache=False)
    res = sess.execute(queries)
    st = res.stats
    # every atom key recurs (census candidates), but the guards prune D so
    # hard that no candidate's summed E[count(D)]/|R| reaches break-even
    assert st.shared_candidate_keys >= 1
    assert st.shared_rejected_keys >= 1
    assert all(s < 4.0 for s in st.sharing_frac_sums.values())
    # rejected atoms evaluated per query: results still bit-identical
    for tree, bm in zip(queries, res.bitmaps):
        want, _, _ = run_query(tree, forest, planner="deepfish",
                               engine="numpy", rewrite_strings=False)
        np.testing.assert_array_equal(bm, want)


def test_selective_sharing_margin_none_restores_census(forest):
    queries = _shared_atom_workload(forest)
    strict = QuerySession(forest, planner="deepfish", engine="numpy",
                          batched=False, annotate=False,
                          persist_atom_cache=False)
    census = QuerySession(forest, planner="deepfish", engine="numpy",
                          batched=False, annotate=False,
                          persist_atom_cache=False, share_margin=None)
    r_strict = strict.execute(queries)
    r_census = census.execute(queries)
    assert (r_census.stats.shared_atom_keys
            == r_census.stats.shared_candidate_keys)
    assert (r_strict.stats.shared_atom_keys
            < r_strict.stats.shared_candidate_keys)
    # census promotion pays |R| per shared atom; the heuristic keeps the
    # guarded count(D) gathers instead — far fewer records touched (the
    # application COUNT goes up: that is the trade being cost-modeled)
    assert (r_strict.backend.records_touched
            < r_census.backend.records_touched)
    for a, b in zip(r_strict.bitmaps, r_census.bitmaps):
        np.testing.assert_array_equal(a, b)


def test_selective_sharing_promotes_profitable_atoms(forest):
    # atoms applied early (frac ~1) across many queries clear the margin
    queries = _workload(forest, 16, 2, seed=8)
    sess = QuerySession(forest, planner="deepfish", engine="numpy",
                        batched=False, persist_atom_cache=False)
    res = sess.execute(queries)
    assert res.stats.shared_atom_keys >= 1
    assert res.stats.dedupe_ratio > 1.0


# -- dictionary-atom plan-cache buckets ---------------------------------------

def test_canonical_key_dict_atoms_use_tight_buckets():
    from repro.core.predicate import code_column
    def tree(sel, col="city#codes"):
        return normalize(And([Atom(col, "eq", 3, selectivity=sel),
                              Atom("x0", "lt", 1.0, selectivity=0.5)]))
    # a numeric atom drifting 0.30 -> 0.32 stays in its 0.05 bucket...
    base, _ = canonical_key(tree(0.30, col="x1"))
    same, _ = canonical_key(tree(0.32, col="x1"))
    assert base == same
    # ...but a dict-code atom with the same drift changes key (its
    # selectivity is exact, bucketed at DICT_SEL_STEP)
    dbase, _ = canonical_key(tree(0.30))
    ddiff, _ = canonical_key(tree(0.32))
    assert dbase != ddiff
    # tiny jitter still hits
    dsame, _ = canonical_key(tree(0.301))
    assert dbase == dsame
    # opting out restores the coarse bucket
    cbase, _ = canonical_key(tree(0.30), dict_sel_step=None)
    csame, _ = canonical_key(tree(0.32), dict_sel_step=None)
    assert cbase == csame
