"""Streaming ingest: appends, mergeable dictionaries, zone maps, deltas."""
import numpy as np
import pytest

import repro.columnar.table as table_mod
from repro.columnar import (BitmapBackend, JaxBlockBackend, extend_bitmap,
                            make_forest_table, pack_bits, run_query,
                            unpack_bits)
from repro.columnar.table import Table, build_dict_column
from repro.core import (And, Atom, PerAtomCostModel, deepfish, execute_plan,
                        normalize)
from repro.core.predicate import (ZONE_ALL, ZONE_MAYBE, ZONE_NONE,
                                  zone_verdicts)


def _mini(n=4000, seed=7, strings=False):
    return make_forest_table(n, n_dup=1, seed=seed, strings=strings)


def _rows_like(table, n, seed):
    src = make_forest_table(n, n_dup=1, seed=seed,
                            strings=any(c.dtype.kind in "USO"
                                        for c in table.columns.values()))
    return {name: src.columns[name] for name in table.columns}


# -- Table.append / delta_since ----------------------------------------------

def test_append_basic_and_delta_since():
    t = _mini()
    v0 = t.version
    old = {k: v.copy() for k, v in t.columns.items()}
    start = t.append(_rows_like(t, 500, seed=1))
    assert start == 4000 and t.n_records == 4500
    assert t.version == v0 + 1
    for name, col in t.columns.items():
        assert len(col) == 4500
        np.testing.assert_array_equal(col[:4000], old[name])
    # delta explanation: everything below the boundary is untouched
    assert t.delta_since(v0) == 4000
    assert t.delta_since(t.version) == 4500          # nothing changed since
    t.append(_rows_like(t, 100, seed=2))
    assert t.delta_since(v0) == 4000                 # min over both appends
    # a rewrite is NOT explainable as an append
    t.set_column("slope_0", t.columns["slope_0"].copy())
    assert t.delta_since(v0) is None
    # ...unless the question is scoped to untouched columns
    assert t.delta_since(v0, columns={"elevation_0"}) == 4000


def test_append_validates_columns():
    t = _mini(1000)
    with pytest.raises(ValueError):
        t.append({"elevation_0": np.zeros(5, np.float32)})
    rows = _rows_like(t, 5, seed=3)
    rows["bogus"] = np.zeros(5)
    with pytest.raises(ValueError):
        t.append(rows)
    ragged = _rows_like(t, 5, seed=3)
    ragged["slope_0"] = ragged["slope_0"][:2]
    with pytest.raises(ValueError):
        t.append(ragged)


def test_delta_since_beyond_log_is_conservative():
    t = _mini(500)
    v0 = t.version
    for i in range(t._MUTLOG_CAP + 5):
        t.append(_rows_like(t, 1, seed=i))
    assert t.delta_since(v0) is None
    assert t.delta_since(t.version - 1) is not None


# -- mergeable dictionaries ---------------------------------------------------

def test_dict_merge_no_full_rebuild(monkeypatch):
    t = _mini(3000, strings=True)
    dc = t.dict_column("cover_0")
    old_codes = dc.codes.copy()

    def boom(col):  # pragma: no cover - the assertion is that it never runs
        raise AssertionError("append must not rebuild the dictionary")

    monkeypatch.setattr(table_mod, "build_dict_column", boom)
    t.append(_rows_like(t, 400, seed=11))
    dc2 = t.dict_column("cover_0")
    assert dc2 is dc                                # merged, not rebuilt
    np.testing.assert_array_equal(dc.codes[:3000], old_codes)
    np.testing.assert_array_equal(dc.decode(), t.columns["cover_0"])
    assert dc.counts.sum() == t.n_records
    assert abs(dc.freqs.sum() - 1.0) < 1e-9


def test_dict_merge_appends_new_values_keeps_old_codes():
    col = np.array(["b", "d", "b", "f"])
    dc = build_dict_column(col)
    assert dc.is_sorted
    info = dc.merge_append(np.array(["a", "d", "z", "a"]))
    assert info["new_values"] == 2 and not info["recoded"]
    # old codes untouched; new values appended past the old code space
    np.testing.assert_array_equal(dc.codes[:4], build_dict_column(col).codes)
    np.testing.assert_array_equal(dc.decode(),
                                  np.concatenate([col, ["a", "d", "z", "a"]]))
    assert not dc.is_sorted                         # "a" landed out of order
    assert dc.encode("a") == int(np.nonzero(dc.values == "a")[0][0])
    assert dc.encode("missing") is None


def test_dict_sorted_extension_stays_sorted():
    dc = build_dict_column(np.array(["a", "c"]))
    info = dc.merge_append(np.array(["x", "z", "x"]))
    assert info["new_values"] == 2 and dc.is_sorted


def test_dict_recode_on_overflow():
    dc = build_dict_column(np.array(["m", "m"]))
    tail = np.array([f"a{i:02d}" for i in range(20)])
    info = dc.merge_append(tail)                    # overflow: 20 unsorted
    assert info["recoded"] and dc.is_sorted
    np.testing.assert_array_equal(dc.decode(),
                                  np.concatenate([["m", "m"], tail]))
    np.testing.assert_array_equal(dc.values, np.sort(dc.values))


def test_dict_recode_surfaces_as_column_write():
    t = _mini(800, strings=True)
    t.dict_column("cover_0")                        # build before appending
    v0 = t.version
    rows = _rows_like(t, 400, seed=5)
    rows["cover_0"] = np.array(
        [f"aa{i % 40:02d}" for i in range(400)])    # floods new low values
    t.append(rows)
    dc = t.dict_column("cover_0")
    assert dc.is_sorted                             # recode happened
    np.testing.assert_array_equal(dc.decode(), t.columns["cover_0"])
    # the recode invalidates code-space caches for THAT column only
    assert t.delta_since(v0) is None
    assert t.delta_since(v0, columns={"cover_0"}) is None
    assert t.delta_since(v0, columns={"cover_0#codes"}) is None
    assert t.delta_since(v0, columns={"elevation_0"}) == 800


def test_rewrite_after_merge_bit_identical():
    """Code-space rewrites on a merged (possibly unsorted) dictionary match
    the numpy oracle on the raw strings."""
    t = _mini(2000, strings=True)
    t.dict_column("cover_0")
    rows = _rows_like(t, 600, seed=13)
    rows["cover_0"] = np.random.default_rng(0).choice(
        np.array(["alder", "beech", "yew", "spruce", "pine"]), 600)
    t.append(rows)
    tree = normalize(And([Atom("cover_0", "in", ("alder", "pine", "yew")),
                          Atom("slope_0", "lt",
                               t.value_at_selectivity("slope_0", 0.6))]))
    got, _, _ = run_query(tree, t, planner="deepfish", engine="numpy",
                          rewrite_strings=True)
    want, _, _ = run_query(tree, t, planner="deepfish", engine="numpy",
                           rewrite_strings=False)
    np.testing.assert_array_equal(got, want)


# -- zone maps ----------------------------------------------------------------

def test_zone_map_bounds_and_incremental_extension():
    t = _mini(3000)
    zm = t.zone_map("elevation_0", 256)
    col = t.columns["elevation_0"]
    for i in range(zm.nblocks):
        blk = col[i * 256:(i + 1) * 256]
        assert zm.mins[i] == blk.min() and zm.maxs[i] == blk.max()
    frozen = zm.mins[:5].copy()
    t.append(_rows_like(t, 700, seed=21))
    zm2 = t.zone_map("elevation_0", 256)
    assert zm2 is zm and zm2.n_rows == 3700         # extended in place
    np.testing.assert_array_equal(zm2.mins[:5], frozen)
    col = t.columns["elevation_0"]
    for i in range(zm2.nblocks):
        blk = col[i * 256:(i + 1) * 256]
        assert zm2.mins[i] == blk.min() and zm2.maxs[i] == blk.max()
    # rewrite rebuilds
    t.set_column("elevation_0", col[::-1].copy())
    zm3 = t.zone_map("elevation_0", 256)
    assert zm3 is not zm


def test_zone_map_resolves_dict_code_column():
    t = _mini(1000, strings=True)
    zm = t.zone_map("cover_0#codes", 128)
    codes = t.dict_column("cover_0").codes
    assert zm is not None
    assert zm.maxs.max() == codes.max()
    assert t.zone_map("cover_0", 128) is None       # raw strings: no bounds


@pytest.mark.parametrize("op,value", [
    ("lt", 120.0), ("le", 120.0), ("gt", 120.0), ("ge", 120.0),
    ("eq", 3), ("ne", 3), ("in", (1, 2, 9)), ("not_in", (1, 2, 9)),
])
def test_zone_verdicts_sound(op, value):
    rng = np.random.default_rng(0)
    col = rng.integers(0, 12, 4096).astype(np.float64)
    col[:1024] = np.sort(col[:1024])                # give ALL/NONE a chance
    block = 128
    mins = col.reshape(-1, block).min(axis=1)
    maxs = col.reshape(-1, block).max(axis=1)
    verd = zone_verdicts(Atom("c", op, value), mins, maxs)
    assert verd is not None
    from repro.columnar.table import _apply_op
    for i in range(len(mins)):
        hits = _apply_op(Atom("c", op, value), col[i * block:(i + 1) * block])
        if verd[i] == ZONE_NONE:
            assert not hits.any()
        elif verd[i] == ZONE_ALL:
            assert hits.all()


def test_zone_verdicts_nan_bounds_stay_maybe():
    """NaN block bounds must never produce a definite verdict — including
    through the in/not_in negations (regression: ~hit_possible used to
    turn NaN-uncertainty into NONE/ALL)."""
    mins = np.array([np.nan, 1.0])
    maxs = np.array([np.nan, 9.0])
    for op, value in (("in", (5.0,)), ("not_in", (5.0,)), ("lt", 5.0),
                      ("eq", 5.0), ("ne", 5.0)):
        verd = zone_verdicts(Atom("c", op, value), mins, maxs)
        assert verd[0] == ZONE_MAYBE, op


def test_zone_pruned_in_fallback_matches_exact_eval():
    """The host-gather fallback evaluates in float64, so its zone verdicts
    must too (regression: f32-rounded bounds declared ALL for blocks whose
    int values collide in float32)."""
    n = 2048
    col = np.full(n, 16777216, dtype=np.int64)
    col[1024:] = 16777217                 # == 16777216 after f32 rounding
    t = Table({"c": col, "x": np.arange(n, dtype=np.float32)})
    tree = normalize(And([Atom("c", "in", (16777216,)),
                          Atom("x", "ge", 0.0, selectivity=0.9)]))
    want, _, _ = run_query(tree, t, planner="deepfish", engine="numpy")
    plan = deepfish(tree, PerAtomCostModel(), total_records=n)
    jb = JaxBlockBackend(t, block=1024, engine="jax")
    got = execute_plan(plan, jb)
    np.testing.assert_array_equal(got, want)


def test_append_failure_leaves_table_unchanged():
    """A tail that fails to cast must not land partially (dict codes
    extended while columns stay old)."""
    t = _mini(500, strings=True)
    dc = t.dict_column("cover_0")
    n_codes = len(dc.codes)
    rows = _rows_like(t, 10, seed=3)
    rows["elevation_0"] = np.array(["not", "a", "float"] * 4)[:10]
    with pytest.raises(ValueError):
        t.append(rows)
    assert t.n_records == 500 and len(t.dict_column("cover_0").codes) == n_codes
    assert t.delta_since(t.version) == 500


def test_zone_verdicts_opaque_atoms_are_skipped():
    mins, maxs = np.zeros(4), np.ones(4)
    assert zone_verdicts(Atom("c", "like", "a%"), mins, maxs) is None
    assert zone_verdicts(Atom("c", "udf", fn=lambda v: v > 0), mins,
                         maxs) is None
    assert zone_verdicts(Atom("c", "lt", "strings"), mins, maxs) is None


def test_zone_pruning_bit_identical_and_prunes():
    t = _mini(20_000)
    t.set_column("clustered", np.sort(t.columns["elevation_0"]).copy())
    cut = float(np.quantile(t.columns["clustered"], 0.35))
    tree = normalize(And([
        Atom("clustered", "lt", cut, selectivity=0.35),
        Atom("slope_0", "lt", t.value_at_selectivity("slope_0", 0.5),
             selectivity=0.5)]))
    model = PerAtomCostModel()
    plan = deepfish(tree, model, total_records=t.n_records)
    want = execute_plan(plan, BitmapBackend(t))
    # numpy engine with zone pruning enabled
    zb = BitmapBackend(t, zone_block=1024)
    got = execute_plan(plan, zb)
    np.testing.assert_array_equal(got, want)
    assert zb.blocks_pruned > 0
    assert zb.records_touched < BitmapBackend(t).n * 2
    # block engine prunes by default
    jb = JaxBlockBackend(t, block=1024, engine="jax")
    got = execute_plan(plan, jb)
    np.testing.assert_array_equal(got, want)
    assert jb.blocks_pruned > 0
    # and with pruning disabled results do not change
    jb0 = JaxBlockBackend(t, block=1024, engine="jax", zone_prune=False)
    got0 = execute_plan(plan, jb0)
    np.testing.assert_array_equal(got0, want)
    assert jb0.blocks_pruned == 0
    assert jb.blocks_touched < jb0.blocks_touched


# -- extend_bitmap ------------------------------------------------------------

@pytest.mark.parametrize("old_n,delta_n", [(0, 40), (32, 32), (37, 61),
                                           (100, 1), (63, 200)])
def test_extend_bitmap_matches_repack(old_n, delta_n):
    rng = np.random.default_rng(old_n + delta_n)
    a = rng.random(old_n) < 0.5
    b = rng.random(delta_n) < 0.5
    got = extend_bitmap(pack_bits(a) if old_n else np.zeros(0, np.uint32),
                        old_n, b, old_n + delta_n)
    np.testing.assert_array_equal(got, pack_bits(np.concatenate([a, b])))
    np.testing.assert_array_equal(unpack_bits(got, old_n + delta_n),
                                  np.concatenate([a, b]))


# hypothesis property sweeps live in tests/test_ingest_property.py (their
# module-level importorskip must not skip the deterministic tests above)


# -- mergeable quantile sketches ----------------------------------------------

def test_quantile_sketch_single_chunk_exact():
    """Columns at or below one sketch chunk keep the exact quantile grid
    the estimator always used."""
    t = _mini(5000)
    got = t.stats("elevation_0").quantiles
    want = np.quantile(t.columns["elevation_0"],
                       np.linspace(0.0, 1.0, len(got)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_quantile_sketch_merge_drift_bounded():
    """Merged multi-chunk estimates stay within a fraction of a selectivity
    point of the full np.quantile rebuild — across an append sequence."""
    from repro.columnar import ingest as ingest_mod
    t = _mini(3000)
    # shrink the chunk so the test table spans many chunks
    old = ingest_mod.SKETCH_CHUNK
    ingest_mod.SKETCH_CHUNK = 512
    try:
        for rnd in range(3):
            if rnd:
                t.append(_rows_like(t, 700, seed=30 + rnd))
            for name in ("elevation_0", "h_dist_road_0", "slope_0"):
                got = t.stats(name).quantiles
                grid = np.linspace(0.0, 1.0, len(got))
                want = np.quantile(t.columns[name], grid)
                # compare as selectivity drift through the estimated CDF
                est = np.interp(want, got, grid)
                assert np.abs(est - grid).max() < 0.02, (name, rnd)
    finally:
        ingest_mod.SKETCH_CHUNK = old


def test_quantile_sketch_extends_incrementally_on_append():
    """Appends recompute only chunks at/past the boundary (the zone-map
    pattern) — prefix chunk summaries are reused by identity."""
    from repro.columnar import ingest as ingest_mod
    old = ingest_mod.SKETCH_CHUNK
    ingest_mod.SKETCH_CHUNK = 1024
    try:
        t = _mini(4000)
        t.stats("elevation_0")
        sk = t._qsketch["elevation_0"][2]
        frozen = [id(g) for g in sk.grids[:3]]      # full prefix chunks
        t.append(_rows_like(t, 600, seed=9))
        got = t.stats("elevation_0").quantiles      # triggers extension
        sk2 = t._qsketch["elevation_0"][2]
        assert sk2 is sk and sk2.n_rows == 4600
        assert [id(g) for g in sk2.grids[:3]] == frozen
        assert len(got) == len(np.unique(got)) or np.all(np.diff(got) >= 0)
        # a rewrite rebuilds from scratch
        t.set_column("elevation_0", t.columns["elevation_0"][::-1].copy())
        t.stats("elevation_0")
        assert t._qsketch["elevation_0"][2] is not sk
    finally:
        ingest_mod.SKETCH_CHUNK = old
