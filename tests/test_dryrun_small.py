"""Multi-device behaviors in a SUBPROCESS (host-device count is locked at
first jax init, so these cannot run in the main pytest process):

* tiny-config lower+compile on a (4, 4) mesh for train/prefill/decode,
  including the shard_map MoE expert-parallel path,
* EP MoE output == single-device oracle,
* elastic checkpoint restore across different mesh shapes,
* int8 compressed all-reduce under shard_map on a pod axis.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke
    from repro.models import api, SHAPES
    from repro.models.config import ShapeCell
    from repro.sharding import use_mesh
    from repro.launch.dryrun import build_step
    from repro.launch.roofline import collective_bytes

    out = {}
    mesh = jax.make_mesh((4, 4), ("data", "model"))

    # 1) lower + compile tiny cells on the mesh (dense + moe + ssm)
    for arch in ("granite-3-8b", "qwen3-moe-30b-a3b", "zamba2-1.2b"):
        cfg = get_smoke(arch)
        for kind, cell in (("train", ShapeCell("t", 64, 8, "train")),
                           ("decode", ShapeCell("d", 64, 8, "decode"))):
            with use_mesh(mesh):
                fn, args = build_step(cfg, cell, mesh)
                compiled = fn.lower(*args).compile()
                txt = compiled.as_text()
            cb = collective_bytes(txt)
            out[f"{arch}:{kind}:collective_bytes"] = cb.get("total", 0.0)

    # 2) EP MoE == local oracle
    from repro.models import moe
    from repro.models.common import init_params
    cfg = get_smoke("qwen3-moe-30b-a3b")
    p = init_params(moe.moe_schema(cfg, 0), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y_local, aux_local = moe.moe_apply(cfg, p, x)      # no mesh -> oracle
    with use_mesh(mesh):
        y_ep, aux_ep = jax.jit(lambda pp, xx: moe.moe_apply(cfg, pp, xx))(p, x)
    d = float(jnp.max(jnp.abs(y_local.astype(jnp.float32)
                              - y_ep.astype(jnp.float32))))
    out["moe_ep_vs_local_maxdiff"] = d
    out["moe_aux_diff"] = abs(float(aux_local) - float(aux_ep))

    # 3) elastic restore across meshes
    from repro.ckpt import save_pytree, load_pytree
    from repro.sharding import named_sharding
    import tempfile
    cfg = get_smoke("granite-3-8b")
    params = api.init(cfg, jax.random.PRNGKey(0))
    tmp = tempfile.mkdtemp()
    save_pytree({"params": params}, tmp, 1)
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    with use_mesh(mesh2):
        shardings = jax.tree.map(
            lambda sp: NamedSharding(mesh2, sp), api.pspecs(cfg, mesh2),
            is_leaf=lambda z: type(z).__name__ == "PartitionSpec")
        tree, _ = load_pytree(tmp, shardings={"params": shardings})
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(params),
                             jax.tree.leaves(tree["params"])))
    out["elastic_restore_exact"] = bool(ok)

    # 4) compressed all-reduce mean over a pod axis
    from repro.train.compress import compressed_allreduce_mean
    from jax.experimental.shard_map import shard_map
    pmesh = jax.make_mesh((4, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(2), (4, 128), jnp.float32)
    want = g.mean(axis=0, keepdims=True)
    got = shard_map(lambda x: compressed_allreduce_mean(x, "pod"),
                    mesh=pmesh, in_specs=P("pod", None),
                    out_specs=P("pod", None), check_rep=False)(g)
    err = float(jnp.max(jnp.abs(got - jnp.broadcast_to(want, got.shape))))
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    out["compressed_ar_err"] = err
    out["compressed_ar_bound"] = scale
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


def test_mesh_cells_compile_and_emit_collectives(subproc_results):
    r = subproc_results
    for arch in ("granite-3-8b", "qwen3-moe-30b-a3b", "zamba2-1.2b"):
        assert r[f"{arch}:train:collective_bytes"] > 0, arch
        assert f"{arch}:decode:collective_bytes" in r


def test_moe_ep_matches_local_oracle(subproc_results):
    assert subproc_results["moe_ep_vs_local_maxdiff"] < 0.15
    assert subproc_results["moe_aux_diff"] < 1e-5


def test_elastic_restore(subproc_results):
    assert subproc_results["elastic_restore_exact"] is True


def test_compressed_allreduce_error_bounded(subproc_results):
    r = subproc_results
    assert r["compressed_ar_err"] <= r["compressed_ar_bound"] + 1e-6
