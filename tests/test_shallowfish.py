"""ShallowFish: correctness (Thm 4), exactly-once atoms (Thm 3),
Algorithm 4 == BestD machine equivalence, Example 1 reproduction."""
import itertools

import numpy as np
import pytest

from repro.core import (Atom, BestDMachine, MemoryCostModel, PerAtomCostModel,
                        VertexBackend, execute_bestd, normalize, orderp,
                        plan_cost, shallowfish, shallowfish_execute)


def example1():
    A = Atom("a", "lt", 1, selectivity=0.820, name="A")
    B = Atom("b", "lt", 1, selectivity=0.313, name="B")
    C = Atom("c", "lt", 1, selectivity=0.469, name="C")
    D = Atom("d", "lt", 1, selectivity=0.984, name="D")
    return normalize(A & (B | (C & D)))


def random_tree(rng, n_atoms=6, depth=3):
    """Small random normalized tree over abstract atoms."""
    from repro.core import And, Or

    def build(level, quota, kind):
        if quota == 1 or level >= depth:
            g = float(rng.uniform(0.05, 0.95))
            i = next(counter)
            return Atom(f"x{i}", "lt", i, selectivity=g,
                        cost_factor=float(rng.uniform(1, 4)))
        k = int(rng.integers(2, min(4, quota) + 1))
        parts = np.diff(np.concatenate([[0], np.sort(rng.choice(
            np.arange(1, quota), size=k - 1, replace=False)), [quota]]))
        sub = Or if kind is And else And
        return kind([build(level + 1, int(p), sub) for p in parts])

    counter = iter(range(100))
    from repro.core import And as A_, Or as O_
    root = build(1, n_atoms, A_ if rng.random() < .5 else O_)
    return normalize(root)


def test_example1_costs():
    t = example1()
    ids = {a.name: a.aid for a in t.atoms}
    m = PerAtomCostModel()
    assert abs(plan_cost(t, [ids[x] for x in "CDBA"], m) - 2.638) < 1e-3
    assert abs(plan_cost(t, [ids[x] for x in "BCAD"], m) - 2.586) < 1e-3


def test_example1_shallowfish_order():
    t = example1()
    plan = shallowfish(t, PerAtomCostModel())
    names = [t.atoms[i].name for i in plan.order]
    assert names == ["C", "D", "B", "A"]
    assert abs(plan.est_cost - 2.638) < 1e-3


def test_correctness_thm4():
    rng = np.random.default_rng(0)
    for trial in range(25):
        t = random_tree(rng, n_atoms=int(rng.integers(3, 8)),
                        depth=int(rng.integers(2, 4)))
        be = VertexBackend(t)
        res = execute_bestd(t, orderp(t), be)
        assert res == frozenset(t.satisfying_vertices())


def test_correctness_any_order():
    """BestD yields psi*(D) for ANY atom ordering (Thm 4/5 hold per order)."""
    rng = np.random.default_rng(1)
    t = random_tree(rng, n_atoms=5, depth=3)
    truth = frozenset(t.satisfying_vertices())
    for perm in itertools.permutations(range(t.n)):
        be = VertexBackend(t)
        assert execute_bestd(t, list(perm), be) == truth


def test_each_atom_exactly_once_thm3():
    rng = np.random.default_rng(2)
    for _ in range(10):
        t = random_tree(rng, n_atoms=6, depth=3)
        be = VertexBackend(t)
        machine = BestDMachine(t, be)
        machine.run(orderp(t))
        assert be.stats.atom_applications == t.n
        assert sorted(machine.order) == list(range(t.n))


def test_alg4_equals_bestd_machine():
    """Optimized ShallowFish (Alg 4) applies atoms to the same record sets
    as the BestD machine for OrderP's depth-first orders."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        t = random_tree(rng, n_atoms=int(rng.integers(3, 8)),
                        depth=int(rng.integers(2, 4)))
        order = orderp(t)
        be1 = VertexBackend(t)
        r1 = execute_bestd(t, order, be1)
        be2 = VertexBackend(t)
        r2 = shallowfish_execute(t, be2, order)
        assert r1 == r2
        assert abs(be1.stats.records_evaluated
                   - be2.stats.records_evaluated) < 1e-9
        assert be1.stats.atom_applications == be2.stats.atom_applications


def test_estimator_matches_vertex_measure():
    """The analytic estimator's step fractions equal the vertex-set measure
    of BestD's D_i under the product distribution."""
    from repro.core import step_fractions
    rng = np.random.default_rng(4)
    for _ in range(10):
        t = random_tree(rng, n_atoms=5, depth=3)
        order = orderp(t)
        be = VertexBackend(t)
        machine = BestDMachine(t, be)
        actual = []
        for aid in order:
            d_i, _ = machine.apply_step(aid)
            actual.append(be.count(d_i))
        est = step_fractions(t, order)
        np.testing.assert_allclose(actual, est, rtol=1e-9, atol=1e-12)
