"""Observability plane: metrics registry, trace spans, EXPLAIN ANALYZE,
serving endpoints — and the hard contract that none of it perturbs
execution (bit-identical results, identical sync/retrace counts with
telemetry on or off)."""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.columnar import (ExecConfig, QuerySession, StreamQueryError,
                            StreamSession, Tracer, explain_analyze,
                            make_forest_table, random_tree)
from repro.columnar.drainer import DrainPolicy
from repro.core import Atom
from repro.runtime import faults
from repro.runtime.telemetry import (MetricsRegistry, TelemetryError,
                                     parse_prometheus)


@pytest.fixture(autouse=True)
def _clean_plane():
    faults.fault_plane().clear()
    yield
    faults.fault_plane().clear()


def _trees(table, k, seed=0):
    rng = np.random.default_rng(seed)
    return [random_tree(table, 4, 2, rng) for _ in range(k)]


# -- registry units -----------------------------------------------------------

def test_counter_gauge_label_cells():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(2, lane="bulk")
    c.inc(3, lane="bulk")
    assert c.value() == 1
    assert c.value(lane="bulk") == 5
    g = reg.gauge("depth")
    g.set(7, lane="x")
    g.dec(2, lane="x")
    assert g.value(lane="x") == 5
    # get-or-create returns the same instance; type clash raises
    assert reg.counter("reqs_total") is c
    with pytest.raises(TelemetryError):
        reg.gauge("reqs_total")
    with pytest.raises(TelemetryError):
        c.inc(-1)


def test_histogram_bucket_edges_inclusive_le():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 10.0, 100.0))
    # exactly on an edge counts in that bucket (le semantics), above the
    # last bucket lands only in +Inf
    for v in (0.5, 1.0, 10.0, 99.9, 1000.0):
        h.observe(v)
    cell = h.snapshot_cell()
    assert cell["counts"] == [2, 1, 1, 1]    # per-bucket, +Inf tail last
    assert cell["count"] == 5
    assert cell["sum"] == pytest.approx(sum((0.5, 1.0, 10.0, 99.9, 1000.0)))
    with pytest.raises(TelemetryError):
        reg.histogram("bad", buckets=(5.0, 5.0))
    with pytest.raises(TelemetryError):      # bucket mismatch on re-get
        reg.histogram("lat", buckets=(1.0, 2.0))


def test_concurrent_publish_is_exact():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("v", buckets=(10.0, 100.0))

    def work():
        for i in range(1000):
            c.inc()
            h.observe(float(i % 150))

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value() == 8000
    assert h.snapshot_cell()["count"] == 8000


def test_prometheus_render_round_trip():
    reg = MetricsRegistry()
    reg.counter("c_total", "help text").inc(3, engine="tape", shards=2)
    reg.gauge("g").set(1.5)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    text = reg.render_prometheus()
    assert "# HELP c_total help text" in text
    assert "# TYPE c_total counter" in text
    parsed = parse_prometheus(text)
    assert parsed[("c_total", (("engine", "tape"), ("shards", "2")))] == 3
    assert parsed[("g", ())] == 1.5
    # histogram explodes into _bucket/_sum/_count series
    assert parsed[("h_bucket", (("le", "2"),))] == 1
    assert parsed[("h_bucket", (("le", "+Inf"),))] == 1
    assert parsed[("h_count", ())] == 1
    # label values with quotes/newlines survive the escaping
    reg.counter("esc_total").inc(1, q='sp"am\negg\\s')
    rt = parse_prometheus(reg.render_prometheus())
    assert rt[("esc_total", (("q", 'sp"am\negg\\s'),))] == 1


# -- tracer units -------------------------------------------------------------

def test_span_nesting_and_ring_bound():
    tr = Tracer(capacity=8)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            tr.event("mark", x=2)
    spans = tr.drain()
    by_name = {s.name: s for s in spans}
    assert by_name["inner"].depth == 1
    assert by_name["inner"].parent_seq == by_name["outer"].seq
    assert by_name["inner"].events[0][0] == "mark"
    assert by_name["outer"].dur_ms >= by_name["inner"].dur_ms
    for i in range(20):                      # ring stays bounded
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 8

    off = Tracer(enabled=False)
    with off.span("ignored"):
        off.event("ignored")
    assert len(off) == 0


def test_stats_protocol_uniform():
    """Every stats surface answers as_dict() with scalars only."""
    t = make_forest_table(2000, n_dup=1, seed=7)
    cfg = ExecConfig(planner="deepfish", engine="numpy",
                     telemetry=False, trace=False)
    sess = QuerySession(t, config=cfg)
    res = sess.execute(_trees(t, 3))
    from repro.core.sets import Stats
    surfaces = [res.stats, sess.plan_cache.stats, Stats()]
    if sess.feedback is not None:
        surfaces.append(sess.feedback)
    for obj in surfaces:
        d = obj.as_dict()
        assert d and all(isinstance(v, (int, float)) for v in d.values())
    # the op log drains into the batch every time (never accumulates
    # undrained on the backend between drains)
    res2 = sess.execute(_trees(t, 3, seed=1))
    assert len(res2.stats.op_observations) <= res2.stats.physical_atoms


# -- the zero-perturbation contract -------------------------------------------

@pytest.mark.parametrize("engine", ["numpy", "tape"])
@pytest.mark.parametrize("planner", ["shallowfish", "deepfish"])
def test_bit_identical_and_contract_equal_with_telemetry(engine, planner,
                                                         forest):
    trees = _trees(forest, 4, seed=3)
    off = QuerySession(forest, config=ExecConfig(
        planner=planner, engine=engine, telemetry=False, trace=False))
    reg, tr = MetricsRegistry(), Tracer()
    on = QuerySession(forest, config=ExecConfig(
        planner=planner, engine=engine, telemetry=reg, trace=tr))
    r_off = off.execute(trees)
    r_on = on.execute(trees)
    for a, b in zip(r_off.bitmaps, r_on.bitmaps):
        np.testing.assert_array_equal(a, b)
    # sync/dispatch/retrace contracts byte-equal between the two runs
    for f in ("host_syncs", "device_dispatches", "host_fallbacks",
              "n_queries", "logical_atoms", "physical_atoms",
              "atom_cache_hits", "plan_cache_hits", "lockstep_rounds"):
        assert getattr(r_off.stats, f) == getattr(r_on.stats, f), f
    # and the observed run actually published
    assert reg.counter("repro_batches_total").value(
        engine=engine, planner=planner, shards=1) == 1
    assert any(s.name == "batch.execute" for s in tr.drain())


def test_batch_publishes_qerror_histograms(forest):
    reg = MetricsRegistry()
    cfg = ExecConfig(planner="deepfish", engine="tape", batched=True,
                     telemetry=reg, trace=False)
    sess = QuerySession(forest, config=cfg)
    sess.execute(_trees(forest, 4, seed=2))
    labels = dict(engine="tape", planner="deepfish", shards=1)
    cell = reg.histogram("repro_op_qerror").snapshot_cell(**labels)
    assert cell is not None and cell["count"] > 0
    assert reg.counter("repro_batch_host_syncs_total").value(**labels) >= 1
    assert reg.histogram("repro_batch_wall_ms").snapshot_cell(
        **labels)["count"] == 1


# -- EXPLAIN ANALYZE ----------------------------------------------------------

def test_explain_analyze_mixed_string_numeric(string_forest):
    q = (Atom("cover_0", "eq", "pine")
         | Atom("elevation_0", "lt",
                float(np.median(string_forest.columns["elevation_0"])))) \
        & Atom("slope_0", "ge", 0.0)
    rep = explain_analyze(q, string_forest,
                          config=ExecConfig(planner="deepfish",
                                            engine="tape"))
    assert rep.engine == "tape" and rep.planner
    assert 0 < rep.selected <= rep.n_records == string_forest.n_records
    assert rep.counters["host_syncs"] == 1       # the contract, visible
    assert rep.plan and rep.plan_order
    assert rep.ops and all(o.src >= o.out >= 0 for o in rep.ops)
    assert rep.max_qerror >= 1.0
    text = rep.render()
    for needle in ("EXPLAIN ANALYZE", "host_syncs=1", "q-err", "cover_0"):
        assert needle in text, needle
    # spans captured for just this query, and JSON-serializable
    assert any(s["name"] == "batch.execute" for s in rep.spans)
    json.dumps(rep.as_dict(), default=str)


def test_explain_analyze_borrowed_session_restores_tracer(forest):
    tr = Tracer()
    sess = QuerySession(forest, config=ExecConfig(
        planner="deepfish", engine="numpy", telemetry=False, trace=tr))
    rep = explain_analyze(_trees(forest, 1, seed=9)[0], session=sess)
    assert sess.tracer is tr                 # swapped back
    assert rep.selected >= 0 and rep.spans


# -- streaming observability --------------------------------------------------

def _stream(table, reg, tr, **kw):
    cfg = ExecConfig(planner="deepfish", engine="tape", batched=True,
                     telemetry=reg, trace=tr)
    return StreamSession(table, config=cfg, **kw)


def test_stream_health_explain_and_latency(forest):
    reg, tr = MetricsRegistry(), Tracer()
    ss = _stream(forest, reg, tr, background=True,
                 policy=DrainPolicy(20.0, 2.0))
    futs = [ss.submit(q, lane="interactive" if i % 2 else "bulk")
            for i, q in enumerate(_trees(forest, 4, seed=5))]
    for f in futs:
        f.result(timeout=30)
    # result() implies the report is already retained (no race)
    for f in futs:
        rep = ss.explain(f)
        assert rep is not None and isinstance(rep.query, str)
    h = ss.health()
    assert h["ok"] and h["drainer_alive"] and h["pending"] == 0
    assert h["last_drain_age_s"] is not None
    lat = reg.histogram("repro_query_latency_ms")
    counts = sum((lat.snapshot_cell(lane=ln) or {"count": 0})["count"]
                 for ln in ("interactive", "bulk"))
    assert counts == 4
    assert reg.gauge("repro_stream_batches").value(
        engine="tape", planner="deepfish", shards=1) >= 1
    ss.close()
    assert not ss.health()["ok"]             # closed -> not ok
    spans = tr.drain()
    names = {s.name for s in spans}
    assert {"stream.drain", "batch.execute", "batch.sync"} <= names
    drain = next(s for s in spans if s.name == "stream.drain")
    assert "queue_wait_ms" in drain.attrs


def test_explain_retention_bounded(forest):
    reg = MetricsRegistry()
    ss = _stream(forest, reg, None)
    ss.explain_capacity = 3
    futs = [ss.submit(q) for q in _trees(forest, 5, seed=6)]
    ss.drain()
    assert len(ss.explain_ids()) == 3        # oldest two evicted
    assert ss.explain(futs[0]) is None
    assert ss.explain(futs[-1]) is not None
    ss.close()


def test_stream_close_flushes_metrics_json(forest, tmp_path):
    reg = MetricsRegistry()
    ss = _stream(forest, reg, None, cache_dir=str(tmp_path))
    fut = ss.submit(_trees(forest, 1, seed=7)[0])
    fut.result(timeout=30)
    ss.close()
    payload = json.loads((tmp_path / "metrics.json").read_text())
    assert payload["stream"]["batches"] == 1
    assert payload["health"]["closed"] is True
    assert any(k.startswith("repro_") for k in payload["registry"])


# -- fault ladder in the registry ---------------------------------------------

def test_degradation_ladder_assertable_from_registry(forest):
    trees = _trees(forest, 3, seed=8)
    reg = MetricsRegistry()
    ss = _stream(forest, reg, None, max_retries=2)

    def rung(name):
        return reg.counter("repro_degradation_total").value(rung=name)

    with faults.inject("device.dispatch", exc=faults.TransientFault,
                       times=1):
        ss.submit(trees[0]).result(timeout=30)
    assert (rung("retry"), rung("fallback"), rung("quarantine")) == (1, 0, 0)

    with faults.inject("device.dispatch", exc=faults.DeviceFault, times=4):
        ss.submit(trees[1]).result(timeout=30)
    assert rung("fallback") == 1 and rung("quarantine") == 0

    with faults.inject("query.plan", exc=lambda: ValueError("poisoned"),
                       times=4, match=lambda ctx: ctx.get("index") == 0):
        f = ss.submit(trees[2])
        with pytest.raises(StreamQueryError):
            f.result(timeout=30)
    assert rung("quarantine") == 1
    # the fault plane itself reported its trips into the global registry
    from repro.runtime.telemetry import registry as global_registry
    assert global_registry().counter("repro_faults_fired_total").value(
        site="device.dispatch") >= 2
    ss.close()


# -- HTTP endpoints -----------------------------------------------------------

def test_httpd_endpoints(forest):
    from urllib.request import urlopen

    from repro.serve.httpd import ObservabilityServer

    reg = MetricsRegistry()
    ss = _stream(forest, reg, Tracer())
    futs = [ss.submit(q) for q in _trees(forest, 2, seed=4)]
    for f in futs:
        f.result(timeout=30)
    with ObservabilityServer(ss) as srv:
        metrics = urlopen(f"{srv.url}/metrics", timeout=10).read().decode()
        parsed = parse_prometheus(metrics)
        key = ("repro_stream_completed",
               (("engine", "tape"), ("planner", "deepfish"),
                ("shards", "1")))
        assert parsed[key] == 2
        health = json.loads(urlopen(f"{srv.url}/healthz",
                                    timeout=10).read())
        assert health["ok"] is True
        listing = json.loads(urlopen(f"{srv.url}/explain",
                                     timeout=10).read())
        assert set(listing["retained"]) == {f.id for f in futs}
        rep = json.loads(urlopen(f"{srv.url}/explain?id={futs[1].id}",
                                 timeout=10).read())
        assert rep["counters"]["host_syncs"] == 1
        text = urlopen(f"{srv.url}/explain?id={futs[1].id}&format=text",
                       timeout=10).read().decode()
        assert "EXPLAIN ANALYZE" in text
    ss.close()


def test_httpd_healthz_surfaces_durability(tmp_path):
    """A durable session's /healthz carries the WAL block and recovery
    state; /metrics carries the ``repro_wal_*`` gauges after a drain."""
    import numpy as np
    from urllib.request import urlopen

    from repro.columnar import make_forest_table
    from repro.serve.httpd import ObservabilityServer

    table = make_forest_table(4000, n_dup=1, seed=7)  # session-private
    n0 = table.n_records
    data_dir = str(tmp_path / "data")
    reg = MetricsRegistry()
    ss = _stream(table, reg, None, durable=data_dir)
    rows = {n: c[:32].copy() for n, c in table.columns.items()}
    ss.append(rows)
    futs = [ss.submit(q) for q in _trees(table, 2, seed=4)]
    for f in futs:
        f.result(timeout=30)
    with ObservabilityServer(ss) as srv:
        health = json.loads(urlopen(f"{srv.url}/healthz",
                                    timeout=10).read())
        assert health["durable"] is True
        assert health["wal"]["uncommitted"] == 0    # drain group-committed
        assert health["wal"]["committed_seq"] >= 2  # create + append
        assert health["recovery"] == {"recovered": False}
        metrics = urlopen(f"{srv.url}/metrics",
                          timeout=10).read().decode()
        assert "repro_wal" in metrics
        assert "repro_wal_commit_ms" in metrics
    ss.close()

    ss2 = _stream(None, reg, None, durable=data_dir)
    with ObservabilityServer(ss2) as srv:
        health = json.loads(urlopen(f"{srv.url}/healthz",
                                    timeout=10).read())
        rec = health["recovery"]
        assert rec["recovered"] is True
        assert rec["recovery_ms"] > 0
    assert ss2.table.n_records == n0 + 32
    np.testing.assert_array_equal(
        ss2.table.columns["elevation_0"][-32:], rows["elevation_0"])
    ss2.close()


def test_httpd_404_and_bad_id(forest):
    from urllib.error import HTTPError
    from urllib.request import urlopen

    from repro.serve.httpd import ObservabilityServer

    ss = _stream(forest, MetricsRegistry(), None)
    with ObservabilityServer(ss) as srv:
        for path in ("/nope", "/explain?id=abc", "/explain?id=12345"):
            with pytest.raises(HTTPError):
                urlopen(f"{srv.url}{path}", timeout=10)
    ss.close()


# -- sharded subprocess: contracts + explain under shard_map ------------------

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.columnar import (ExecConfig, QuerySession, Tracer,
                                explain_analyze, make_forest_table,
                                random_tree, run_query)
    from repro.columnar.device import _TAPE_PROGRAMS
    from repro.core import Atom
    from repro.runtime.telemetry import MetricsRegistry

    t = make_forest_table(20_000, n_dup=1, seed=7, strings=True)
    trees = [random_tree(t, 4, 2, np.random.default_rng(s))
             for s in (1, 2)]
    out = {}

    reg, tr = MetricsRegistry(), Tracer()
    on = QuerySession(t, config=ExecConfig(
        planner="deepfish", engine="tape", batched=True, shards=2,
        telemetry=reg, trace=tr))
    off = QuerySession(t, config=ExecConfig(
        planner="deepfish", engine="tape", batched=True, shards=2,
        telemetry=False, trace=False))
    n0 = len(_TAPE_PROGRAMS)
    r_on, r_off = on.execute(trees), off.execute(trees)
    out["identical"] = all(
        np.array_equal(a, b) for a, b in zip(r_on.bitmaps, r_off.bitmaps))
    out["host_syncs"] = [r_on.stats.host_syncs, r_off.stats.host_syncs]
    out["oracle_ok"] = all(
        np.array_equal(bm, run_query(q, t, config=ExecConfig(
            planner="deepfish"))[0])
        for bm, q in zip(r_on.bitmaps, trees))
    t.append({name: col[:1024] for name, col in t.columns.items()})
    n1 = len(_TAPE_PROGRAMS)
    r2 = on.execute(trees)
    out["programs_compiled_on_append"] = len(_TAPE_PROGRAMS) - n1
    out["spans"] = sorted({s.name for s in tr.drain()})

    med = float(np.median(t.columns["elevation_0"]))
    q = (Atom("cover_0", "eq", "pine")
         | Atom("elevation_0", "lt", med)) & Atom("slope_0", "ge", 0.0)
    rep = explain_analyze(q, t, config=ExecConfig(
        planner="deepfish", engine="tape", shards=2))
    out["explain"] = {"shards": rep.shards, "selected": rep.selected,
                      "host_syncs": rep.counters["host_syncs"],
                      "has_qerr": rep.max_qerror >= 1.0,
                      "rendered": "EXPLAIN ANALYZE" in rep.render()}
    print(json.dumps(out))
""")


def test_sharded_observability_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["identical"] and out["oracle_ok"]
    assert out["host_syncs"] == [1, 1]       # one collective sync, on or off
    assert out["programs_compiled_on_append"] == 0
    assert "batch.sync" in out["spans"]
    assert out["explain"]["shards"] == 2
    assert out["explain"]["host_syncs"] == 1
    assert out["explain"]["has_qerr"] and out["explain"]["rendered"]
