"""Estimator internals: incremental apply() == fresh recompute; plan
determinism; describe() rendering."""
import numpy as np
import pytest

from repro.core import (EstimatorState, PerAtomCostModel, deepfish,
                        plan_cost, shallowfish)
from test_shallowfish import example1, random_tree


def test_incremental_apply_equals_fresh():
    """EstimatorState.apply (lineage-local update, used by DeepFish's
    O(n^2) lookahead) must equal a fresh full recompute."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        t = random_tree(rng, n_atoms=int(rng.integers(3, 9)),
                        depth=int(rng.integers(2, 5)))
        order = list(rng.permutation(t.n))
        st = EstimatorState(t)
        applied = []
        for aid in order:
            st = st.apply(aid)
            applied.append(aid)
            fresh = EstimatorState(t, applied)
            for node_id in st._dt:
                assert abs(st._dt[node_id] - fresh._dt[node_id]) < 1e-12
                assert abs(st._df[node_id] - fresh._df[node_id]) < 1e-12


def test_root_fraction_consistency():
    t = example1()
    st = EstimatorState(t, range(t.n))       # everything applied
    dt, df = st.root_fraction()
    assert abs(dt + df - 1.0) < 1e-9         # fully determined
    # dt == P(phi*) under independence
    gA, gB, gC, gD = 0.820, 0.313, 0.469, 0.984
    want = gA * (gB + (1 - gB) * gC * gD)
    assert abs(dt - want) < 1e-9


def test_plans_are_deterministic():
    rng = np.random.default_rng(1)
    m = PerAtomCostModel()
    for _ in range(5):
        t = random_tree(rng, n_atoms=7, depth=3)
        p1, p2 = shallowfish(t, m), shallowfish(t, m)
        assert p1.order == p2.order and p1.est_cost == p2.est_cost
        d1, d2 = deepfish(t, m), deepfish(t, m)
        assert d1.order == d2.order


def test_plan_describe_and_cost_scaling():
    t = example1()
    m = PerAtomCostModel()
    plan = shallowfish(t, m, total_records=1000.0)
    txt = plan.describe()
    assert "shallowfish" in txt and "step 1" in txt
    # cost scales linearly in |R| when kappa == 0
    assert abs(plan.est_cost - 1000.0 * plan_cost(t, plan.order, m)) < 1e-6
