"""Tombstone deletes: live-mask semantics, cache survival, compaction.

The delete contract: :meth:`Table.delete` marks rows dead without moving
anything or bumping ``table.version`` — every atom cache, device upload,
and zone map stays valid, and the live mask is ANDed into results at
materialize time only.  Compaction is the single row-moving mutation and
invalidates through the normal version/delta contract (``delta_since``
answers None across it).
"""
import numpy as np
import pytest

from repro.columnar import (QuerySession, StreamSession, make_forest_table,
                            pack_bits, random_tree, run_query, unpack_bits)

PLANNERS = ("shallowfish", "deepfish", "optimal")
ENGINES = ("numpy", "jax", "tape")


def _fresh(seed=7, n=4000):
    return make_forest_table(n, n_dup=1, seed=seed)


def _tree(table, seed):
    return random_tree(table, 6, 3, np.random.default_rng(seed))


# -- differential sweep: planners x engines x interleaved append/delete -------

@pytest.mark.parametrize("planner", PLANNERS)
@pytest.mark.parametrize("engine", ENGINES)
def test_deleted_rows_never_match(planner, engine):
    t = _fresh()
    rng = np.random.default_rng(3)
    dead = rng.random(t.n_records) < 0.3
    t.delete(dead)
    for seed in range(4):
        tree = _tree(t, seed)
        res, _, _ = run_query(tree, t, planner=planner, engine=engine)
        mask = unpack_bits(res, t.n_records)
        assert not mask[dead].any()
        # live rows answer exactly as an undeleted twin restricted to them
        twin = _fresh()
        oracle, _, _ = run_query(_tree(twin, seed), twin,
                                 planner="deepfish", engine="numpy")
        omask = unpack_bits(oracle, twin.n_records)
        np.testing.assert_array_equal(mask[~dead], omask[~dead])


@pytest.mark.parametrize("engine", ENGINES)
def test_interleaved_append_delete_matches_oracle(engine):
    # delete -> append -> delete again; appended rows are live, every
    # engine agrees with a hand-built numpy oracle at each step
    t = _fresh(seed=5, n=3000)
    extra = make_forest_table(3000, n_dup=1, seed=9)
    tree_seed = 2
    t.delete(np.arange(0, 1000))
    t.append({name: extra.columns[name][:1500] for name in t.columns})
    t.delete(np.arange(3200, 3400))
    tree = _tree(t, tree_seed)
    res, _, _ = run_query(tree, t, planner="deepfish", engine=engine)
    mask = unpack_bits(res, t.n_records)
    assert not mask[:1000].any() and not mask[3200:3400].any()
    oracle, _, _ = run_query(_tree(t, tree_seed), t,
                             planner="deepfish", engine="numpy")
    np.testing.assert_array_equal(res, oracle)


def test_delete_preserves_version_and_caches():
    t = _fresh()
    s = QuerySession(t, planner="deepfish", engine="numpy")
    tree = _tree(t, 1)
    s.execute([tree])
    v0 = t.version
    t.delete(np.arange(100, 600))
    assert t.version == v0                  # no cache invalidation
    assert t.tombstone_epoch == 1
    res = s.execute([_tree(t, 1)])
    # second batch re-used the session caches (no full re-evaluation) yet
    # excludes the tombstoned rows
    assert not unpack_bits(res.bitmaps[0], t.n_records)[100:600].any()


def test_delete_idempotent_and_epoch():
    t = _fresh(n=1000)
    assert t.delete(np.arange(10)) == 10
    assert t.tombstone_epoch == 1 and t.n_deleted == 10
    assert t.delete(np.arange(10)) == 0     # already dead: no-op
    assert t.tombstone_epoch == 1           # epoch only moves on new deaths
    mask = np.zeros(1000, dtype=bool)
    mask[5:15] = True
    assert t.delete(mask) == 5
    assert t.tombstone_epoch == 2 and t.n_deleted == 15
    with pytest.raises(ValueError):
        t.delete(np.zeros(999, dtype=bool))  # mask length must match
    with pytest.raises(IndexError):
        t.delete([1000])


def test_append_after_delete_keeps_new_rows_live():
    t = _fresh(n=2000)
    extra = make_forest_table(500, n_dup=1, seed=11)
    t.delete(np.arange(2000))               # everything dead
    t.append({name: extra.columns[name] for name in t.columns})
    res, _, _ = run_query(_tree(t, 4), t, planner="deepfish",
                          engine="numpy")
    mask = unpack_bits(res, t.n_records)
    assert not mask[:2000].any()
    lw = t.live_words()
    live = unpack_bits(lw, t.n_records)
    assert not live[:2000].any() and live[2000:].all()


def test_compaction_bumps_epoch_and_version():
    t = _fresh(n=2000)
    # draw the tree from an untouched twin: random_tree samples atom
    # thresholds from the table's value distribution, which compaction
    # shifts — the twin pins both runs to the identical tree
    twin = _fresh(n=2000)
    before, _, _ = run_query(_tree(twin, 6), t, planner="deepfish",
                             engine="numpy")
    keep = np.ones(2000, dtype=bool)
    keep[::3] = False
    t.delete(~keep)
    v0, e0 = t.version, t.tombstone_epoch
    removed = t.compact()
    assert removed == int((~keep).sum())
    assert t.version == v0 + 1              # the cache-invalidating bump
    assert t.tombstone_epoch == e0 + 1
    assert t.n_records == int(keep.sum()) and t.n_deleted == 0
    assert t.delta_since(v0) is None        # rows moved: no delta survives
    # post-compact results equal the pre-compact live projection
    after, _, _ = run_query(_tree(twin, 6), t, planner="deepfish",
                            engine="numpy")
    np.testing.assert_array_equal(
        unpack_bits(after, t.n_records),
        unpack_bits(before, 2000)[keep])


def test_maybe_compact_threshold():
    t = _fresh(n=1000)
    t.delete(np.arange(100))
    assert t.maybe_compact(0.25) == 0       # 10% dead: below threshold
    t.delete(np.arange(100, 300))
    assert t.maybe_compact(0.25) == 300     # 30% dead: compacts
    assert t.n_records == 700


def test_stream_delete_and_auto_compact():
    t = _fresh(n=4000)
    twin = _fresh(n=4000)       # pins identical trees across compaction
    stream = StreamSession(t, engine="numpy", max_pending=64,
                           auto_compact=0.25)
    f0 = stream.submit(_tree(twin, 8))
    stream.drain()
    base = f0.mask()
    assert stream.delete(np.arange(0, 200)) == 200   # 5%: no compaction
    assert stream.stats.compactions == 0
    f1 = stream.submit(_tree(twin, 8))
    stream.drain()
    m1 = f1.mask()
    assert not m1[:200].any()
    np.testing.assert_array_equal(m1[200:], base[200:])
    n1, lw1 = f1.snapshot
    assert n1 == 4000 and lw1 is not None
    stream.delete(np.arange(200, 1300))              # >25%: compacts
    assert stream.stats.compactions == 1
    assert stream.stats.compacted_rows == 1300 and t.n_records == 2700
    f2 = stream.submit(_tree(twin, 8))
    stream.drain()
    np.testing.assert_array_equal(f2.mask(), base[1300:])
    assert f2.snapshot[1] is None           # compacted: no tombstones left


def test_live_words_matches_packed_complement():
    t = _fresh(n=1000)
    assert t.live_words() is None
    rng = np.random.default_rng(0)
    dead = rng.random(1000) < 0.5
    t.delete(dead)
    np.testing.assert_array_equal(t.live_words(), pack_bits(~dead))
    assert abs(t.deleted_fraction - dead.mean()) < 1e-9
