"""Training substrate: optimizers converge, microbatch equivalence,
checkpoint bit-exactness, gradient compression properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import api
from repro.train import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, compress,
                         make_train_step)

KEY = jax.random.PRNGKey(0)


def tiny_cfg(**kw):
    return get_smoke("granite-3-8b").replace(**kw)


def make_batch(cfg, b=4, s=32, seed=0):
    k = jax.random.PRNGKey(seed)
    return {"tokens": jax.random.randint(k, (b, s + 1), 0, cfg.vocab)}


def test_adamw_reduces_loss():
    cfg = tiny_cfg(microbatch=1)
    params = api.init(cfg, KEY)
    step = make_train_step(cfg, lr=5e-3)
    state = step.init_state(params)
    batch = make_batch(cfg)
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_adafactor_reduces_loss():
    cfg = tiny_cfg(microbatch=1, optimizer="adafactor")
    params = api.init(cfg, KEY)
    step = make_train_step(cfg, lr=1e-2)
    state = step.init_state(params)
    batch = make_batch(cfg)
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_microbatch_equivalent_to_full_batch():
    cfg1 = tiny_cfg(microbatch=1)
    cfg2 = tiny_cfg(microbatch=2)
    params = api.init(cfg1, KEY)
    batch = make_batch(cfg1, b=4)
    s1 = make_train_step(cfg1, lr=1e-3)
    s2 = make_train_step(cfg2, lr=1e-3)
    p1, _, m1 = s1(params, s1.init_state(params), batch)
    p2, _, m2 = s2(params, s2.init_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-2, d      # same update up to bf16 accumulation noise


def test_grad_clip():
    tree = {"a": jnp.ones((4,)) * 100.0, "b": jnp.ones((2,)) * 50.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    cn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                            for x in jax.tree.leaves(clipped))))
    assert abs(cn - 1.0) < 1e-5
    assert float(norm) > 100


def test_adafactor_memory_is_factored():
    cfg = tiny_cfg(optimizer="adafactor")
    params = api.init(cfg, KEY)
    st = adafactor_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    n_state = sum(x.size for x in jax.tree.leaves(st["f"]))
    assert n_state < 0.25 * n_params   # factored second moment is tiny


# --- gradient compression ---------------------------------------------------
def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, scale = compress.quantize_int8(x)
    err = np.abs(np.asarray(compress.dequantize_int8(q, scale)) -
                 np.asarray(x)).max()
    assert err <= float(scale) / 2 + 1e-7


def test_error_feedback_is_unbiased_over_time():
    """With error feedback the accumulated compressed sum converges to the
    true sum (EF-SGD property)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32)) * 1e-3
    grads = {"w": g}
    resid = compress.init_residuals(grads)
    total = np.zeros(128, np.float32)
    for _ in range(50):
        deq, resid = compress.compress_tree_with_feedback(grads, resid)
        total += np.asarray(deq["w"], np.float32)
    true = np.asarray(g) * 50
    rel = np.abs(total - true).max() / (np.abs(true).max() + 1e-12)
    assert rel < 0.05, rel


def test_train_step_with_compression_runs():
    cfg = tiny_cfg(microbatch=1)
    params = api.init(cfg, KEY)
    step = make_train_step(cfg, lr=1e-3, grad_compression="int8")
    state = step.init_state(params)
    assert "ef_residual" in state
    batch = make_batch(cfg)
    losses = []
    for _ in range(6):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# --- checkpointing ----------------------------------------------------------
def test_checkpoint_roundtrip_bit_exact(tmp_path):
    from repro.ckpt import load_pytree, save_pytree
    cfg = tiny_cfg()
    params = api.init(cfg, KEY)
    save_pytree({"params": params, "x": jnp.arange(7)}, str(tmp_path), 3)
    tree, manifest = load_pytree(str(tmp_path))
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_gc(tmp_path):
    from repro.ckpt import CheckpointManager, latest_step
    mgr = CheckpointManager(str(tmp_path), keep=2, use_async=False)
    for s in (1, 2, 3, 4):
        mgr.save({"v": jnp.full((3,), s)}, s)
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]               # keep=2 gc'd older
    tree, _ = mgr.restore()
    assert float(tree["v"][0]) == 4


def test_async_checkpointer(tmp_path):
    from repro.ckpt import AsyncCheckpointer, load_pytree
    ck = AsyncCheckpointer()
    ck.save({"a": jnp.ones((5,))}, str(tmp_path), 10)
    ck.wait()
    tree, manifest = load_pytree(str(tmp_path), 10)
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.ones(5))
    ck.close()
