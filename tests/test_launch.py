"""Launch-layer units that run on ONE device: spec construction, shape
cells, roofline HLO parsing, unit solver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.roofline import (Measurement, collective_bytes,
                                   model_flops, model_params_active,
                                   solve_units)
from repro.launch.specs import input_specs
from repro.models import SHAPES, api


def test_input_specs_shapes_train():
    cfg = get_config("granite-3-8b")
    spec = input_specs(cfg, SHAPES["train_4k"])["batch"]
    assert spec["tokens"].shape == (256, 4097)
    assert spec["tokens"].dtype == jnp.int32


def test_input_specs_decode_cache():
    cfg = get_config("yi-9b")
    spec = input_specs(cfg, SHAPES["decode_32k"])
    assert spec["token"].shape == (128, 1)
    kv = spec["cache"]["kv"]
    assert kv["k"].shape == (48, 128, 32768, 4, 128)


def test_input_specs_stub_frontends():
    w = get_config("whisper-base")
    spec = input_specs(w, SHAPES["train_4k"])["batch"]
    assert spec["frames"].shape == (256, 1500, 512)
    v = get_config("llama-3.2-vision-11b")
    spec = input_specs(v, SHAPES["prefill_32k"])["batch"]
    assert spec["vision"].shape == (32, 1601, 4096)


def test_collective_parse():
    hlo = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %notacoll = f32[99]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 4 * 2       # ring x2
    assert out["all-gather"] == 4 * 256 * 2
    assert out["collective-permute"] == 8 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_unit_solver_exact():
    # base=5, unitA=3, unitB=7 reconstructed from 3 variants
    variants = [
        ({"a": 1, "b": 1}, Measurement(5 + 3 + 7, 0, {})),
        ({"a": 2, "b": 1}, Measurement(5 + 6 + 7, 0, {})),
        ({"a": 1, "b": 2}, Measurement(5 + 3 + 14, 0, {})),
    ]
    m = solve_units(variants, {"a": 10, "b": 4})
    assert abs(m.flops - (5 + 30 + 28)) < 1e-6


def test_model_flops_sanity():
    cfg = get_config("granite-3-8b")
    n, n_active = model_params_active(cfg)
    assert n == n_active                      # dense
    assert 7.5e9 < n < 9e9
    f = model_flops(cfg, SHAPES["train_4k"])
    assert abs(f - 6 * n * 256 * 4096) / f < 1e-6
    ds = get_config("deepseek-v3-671b")
    nt, na = model_params_active(ds)
    assert nt > 6e11 and na < 0.1 * nt        # sparse activation


def test_supports_matrix_counts():
    from repro.models import supports_shape
    runnable = sum(supports_shape(get_config(a), s)
                   for a in ARCHS for s in SHAPES)
    assert runnable == 32                     # 40 cells - 8 long_500k skips
