"""Recovery differential contracts (the ISSUE's satellite 3).

A recovered process must be indistinguishable from one that never
crashed: every planner × engine combination answers queries on the
recovered table bit-identically to the pre-crash table AND to a naive
full-scan oracle; the tape engine's execution contracts — one bundled
host sync per drain, no program retrace on append — hold on the
recovered process exactly as they do on a live one.
"""
import numpy as np
import pytest

from repro.columnar import (Durability, ExecConfig, StreamSession, Table,
                            make_forest_table, pack_bits, run_query)
from repro.columnar.queries import random_tree

PLANNERS = ["shallowfish", "deepfish", "nooropt", "optimal"]
ENGINES = ["numpy", "jax", "tape"]


def _rows_like(table, n, seed):
    rng = np.random.default_rng(seed)
    out = {}
    for name, col in table.columns.items():
        if col.dtype.kind in "iu":
            out[name] = rng.integers(col.min(), col.max() + 1, size=n
                                     ).astype(col.dtype)
        elif col.dtype.kind == "f":
            out[name] = rng.uniform(col.min(), col.max(), size=n
                                    ).astype(col.dtype)
        else:
            out[name] = rng.choice(np.unique(col), size=n)
    return out


def _apply_history(table, flavor, checkpoint=lambda: None):
    """Interleaved mutation history; ``flavor`` picks the shape.
    ``checkpoint`` fires mid-history so recoveries exercise snapshot +
    tail replay rather than a pure log replay."""
    rng = np.random.default_rng(hash(flavor) % (1 << 31))
    if flavor == "append-compact":
        table.append(_rows_like(table, 700, seed=1))
        table.delete(rng.integers(0, table.n_records, size=300))
        table.compact()                 # rows moved mid-history
        checkpoint()
        table.append(_rows_like(table, 500, seed=2))
        table.delete(rng.integers(0, table.n_records, size=120))
    else:                               # "delete-heavy": live tombstones
        for i in range(3):
            table.append(_rows_like(table, 300, seed=10 + i))
            if i == 1:
                checkpoint()
            table.delete(rng.integers(0, table.n_records, size=150))


def _oracle(table, tree):
    """Naive full-scan evaluation + live mask, shared with no engine."""
    from repro.core.predicate import And, Atom

    def mask(node):
        if isinstance(node, Atom):
            return table.eval_atom(node, None)
        combine = np.logical_and if isinstance(node, And) \
            else np.logical_or
        out = None
        for c in node.children:
            m = mask(c)
            out = m if out is None else combine(out, m)
        return out

    m = mask(tree.root)
    if table._tombstones is not None:
        live = np.ones(table.n_records, dtype=bool)
        live[: len(table._tombstones)] &= ~table._tombstones
        m = m & live
    return pack_bits(m)


@pytest.mark.parametrize("flavor", ["append-compact", "delete-heavy"])
def test_recovered_table_differential_all_planners_engines(tmp_path,
                                                           flavor):
    live = make_forest_table(4000, n_dup=1, seed=11)
    dur = Durability(str(tmp_path / flavor), snapshot_every=None)
    dur.attach(live)
    _apply_history(live, flavor, checkpoint=dur.snapshot)
    dur.commit()
    dur.close()

    dur2, recovered, info = Durability.recover(str(tmp_path / flavor))
    assert info["n_records"] == live.n_records
    assert info["version"] == live.version
    # the mid-history checkpoint makes this a real snapshot + tail
    # replay, not a pure log replay
    assert info["snapshot_seq"] > 0 and info["replayed_records"] > 0

    trees = [random_tree(recovered, 5, 2, np.random.default_rng(s))
             for s in range(2)]
    for tree in trees:
        want = _oracle(live, tree)
        for planner in PLANNERS:
            for engine in ENGINES:
                cfg = ExecConfig(planner=planner, engine=engine)
                got, _, _ = run_query(tree, recovered, config=cfg)
                np.testing.assert_array_equal(
                    got, want,
                    err_msg=f"{planner}/{engine} diverged on recovery "
                            f"({flavor})")
                ref, _, _ = run_query(tree, live, config=cfg)
                np.testing.assert_array_equal(got, ref)
    dur2.close()


def test_recovered_stream_one_bundled_sync_per_drain(tmp_path):
    """The bundled-sync contract, gated on a RECOVERED process: a tape
    drain on the recovered session pays exactly one host sync."""
    data_dir = str(tmp_path / "data")
    t = make_forest_table(8000, n_dup=1, seed=7)
    trees = [random_tree(t, 4, 2, np.random.default_rng(i))
             for i in range(4)]
    s1 = StreamSession(t, engine="numpy", max_pending=64,
                       durable=data_dir)
    s1.append(_rows_like(t, 500, seed=3))
    s1.sync()
    s1.close()

    s2 = StreamSession(None, engine="tape", block=4096, max_pending=64,
                       durable=data_dir)
    assert s2.recovery_info is not None and s2.table.n_records == 8500
    futs = [s2.submit(tr) for tr in trees]
    s2.drain()
    be = s2.session._backend
    assert be.host_syncs == 1                   # one bundled sync
    s2.append(_rows_like(s2.table, 600, seed=4))
    futs2 = [s2.submit(tr) for tr in trees]
    s2.drain()
    assert be.host_syncs == 2                   # still one per drain
    for f in futs + futs2:
        assert f.result(timeout=60) is not None
    s2.close()


def test_recovered_stream_no_retrace_on_append(tmp_path):
    """Warm plan/tape caches survive recovery (same data epoch), and
    appends on the recovered process compile ZERO new device programs —
    the block-delta no-retrace contract holds after replay."""
    from repro.columnar.device import _TAPE_PROGRAMS

    data_dir = str(tmp_path / "data")
    cache_dir = str(tmp_path / "warm")
    t = make_forest_table(8000, n_dup=1, seed=7)
    trees = [random_tree(t, 5, 3, np.random.default_rng(i))
             for i in range(3)]
    s1 = StreamSession(t, engine="tape", batched="auto", block=2048,
                       max_pending=64, durable=data_dir,
                       cache_dir=cache_dir)
    futs = [s1.submit(tr) for tr in trees]
    s1.drain()
    baseline = [f.result(timeout=60) for f in futs]
    s1.close()

    s2 = StreamSession(None, engine="tape", batched="auto", block=2048,
                       max_pending=64, durable=data_dir,
                       cache_dir=cache_dir)
    assert s2.recovery_info is not None
    assert s2.table.n_records == 8000
    assert s2.restore_info["plans"] >= 3        # same epoch: warm caches

    futs2 = [s2.submit(tr) for tr in trees]
    res = s2.drain()
    assert res.stats.tape_cache_hits >= 3       # rebound, not recompiled
    assert res.stats.plan_cache_hits >= 3
    for f, base in zip(futs2, baseline):
        # bit-identical to the pre-crash results
        np.testing.assert_array_equal(
            np.asarray(f.result(timeout=60)), base)

    # appends on the recovered process: delta splice, zero new programs
    compiled_at_warm = len(_TAPE_PROGRAMS)
    s2.append(_rows_like(s2.table, 700, seed=4))
    futs3 = [s2.submit(tr) for tr in trees]
    s2.drain()
    for f in futs3:
        f.result(timeout=60)
    assert len(_TAPE_PROGRAMS) == compiled_at_warm, \
        "append after recovery recompiled device programs"
    s2.close()


def test_recovered_delete_then_engines_agree(tmp_path):
    """Tombstones created BEFORE the crash and AFTER recovery compose:
    every engine masks both, bit-identically."""
    t = Table({"x": np.arange(3000, dtype=np.int64),
               "y": np.arange(3000, dtype=np.float64) / 7.0})
    s = StreamSession(t, config=ExecConfig(planner="deepfish",
                                           engine="numpy"),
                      durable=str(tmp_path / "d"))
    s.delete(np.arange(0, 3000, 5))
    s.sync()
    s.close()

    s2 = StreamSession(None, config=ExecConfig(planner="deepfish",
                                               engine="numpy"),
                       durable=str(tmp_path / "d"))
    s2.delete(np.arange(0, 3000, 7))
    tree = random_tree(s2.table, 4, 2, np.random.default_rng(1))
    want = _oracle(s2.table, tree)
    for engine in ENGINES:
        got, _, _ = run_query(tree, s2.table,
                              config=ExecConfig(planner="deepfish",
                                                engine=engine))
        np.testing.assert_array_equal(got, want, err_msg=engine)
    s2.close()
